"""Gradient compression for the DP all-reduce, with error feedback.

Two standard compressors (both with EF-SGD-style residual accumulation so
compression error is re-injected next step instead of lost):

* **int8 blockwise** — 4× reduction of all-reduce bytes; quantize → sum of
  dequantized shards (psum runs on the dequantized f32, so this models
  quantize-before-transmit; on real ICI the transfer is the int8 payload).
* **top-k sparsification** — keep the k largest-|g| entries per tensor
  (static k → static shapes), transmit (values, indices); the union-sum is
  realized with a scatter-add after an all-gather of the sparse payloads.

API: ``compressor.compress(grads, residual) → (payload, new_residual)``,
``compressor.decompress(payload) → grads``. The train loop applies them
around the DP reduction (see train_loop.make_train_step's compress hook).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optimizer import Quantized, dequantize_blockwise, quantize_blockwise


class Int8Payload(NamedTuple):
    q: Quantized
    shape: tuple


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """Blockwise int8 with error feedback."""

    def init_residual(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, residual):
        def leaf(g, r):
            x = g.astype(jnp.float32) + r
            q = quantize_blockwise(x)
            deq = dequantize_blockwise(q, x.shape)
            return q, x - deq  # payload, new residual

        pairs = jax.tree.map(leaf, grads, residual, is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, tuple))
        payload = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], Quantized))
        new_residual = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], Quantized))
        return payload, new_residual

    def decompress(self, payload, like):
        return jax.tree.map(
            lambda q, p: dequantize_blockwise(q, p.shape).astype(jnp.float32),
            payload,
            like,
            is_leaf=lambda x: isinstance(x, Quantized),
        )

    def bytes_ratio(self) -> float:
        return 0.25 + 4.0 / 2048  # int8 + f32 scale per 2048 block


class TopKPayload(NamedTuple):
    values: jnp.ndarray  # (k,)
    indices: jnp.ndarray  # (k,) int32 into the flattened tensor
    shape: tuple


@dataclasses.dataclass(frozen=True)
class TopKCompressor:
    """Per-tensor magnitude top-k with error feedback. fraction ∈ (0, 1]."""

    fraction: float = 0.01
    min_k: int = 1

    def init_residual(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def _k(self, n: int) -> int:
        return max(self.min_k, int(np.ceil(n * self.fraction)))

    def compress(self, grads, residual):
        def leaf(g, r):
            x = (g.astype(jnp.float32) + r).reshape(-1)
            k = self._k(x.shape[0])
            _, idx = jax.lax.top_k(jnp.abs(x), k)
            vals = x[idx]
            sparse_only = jnp.zeros_like(x).at[idx].set(vals)
            new_r = (x - sparse_only).reshape(g.shape)
            return TopKPayload(vals, idx.astype(jnp.int32), g.shape), new_r

        is_arr = lambda x: hasattr(x, "shape") and not isinstance(x, TopKPayload)
        pairs = jax.tree.map(leaf, grads, residual, is_leaf=is_arr)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], TopKPayload)
        payload = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
        new_residual = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
        return payload, new_residual

    def decompress(self, payload, like=None):
        def leaf(p: TopKPayload):
            n = int(np.prod(p.shape))
            return jnp.zeros((n,), jnp.float32).at[p.indices].set(p.values).reshape(p.shape)

        return jax.tree.map(leaf, payload, is_leaf=lambda x: isinstance(x, TopKPayload))

    def bytes_ratio(self) -> float:
        return self.fraction * 2.0  # value + index per kept entry


def compressed_psum(grads, residual, compressor, axis_name: str | None):
    """Compress → (psum over DP axis) → decompress. Returns (grads, residual).

    With axis_name=None (single device / outside shard_map) the reduction is
    the identity, so the compression error path is still exercised.
    """
    payload, new_residual = compressor.compress(grads, residual)
    deq = compressor.decompress(payload, grads)
    if axis_name is not None:
        deq = jax.lax.psum(deq, axis_name)
    return deq, new_residual
