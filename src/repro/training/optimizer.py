"""Optimizers in pure JAX: AdamW (f32 or int8-quantized moments), SGD,
schedules, global-norm clipping.

The int8 moment path (Dettmers-style blockwise quantization, block = 2048
flattened elements with per-block absmax scales) is what lets the 1T-param
kimi-k2 config's optimizer state fit 16 GB/chip HBM at 512 chips: moments go
from 8 bytes/param (2×f32) to ~2 bytes/param (2×int8 + scales/2048). This is
a first-class distributed-optimization feature, exercised by the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 2048


# --------------------------------------------------------------------------- #
# Blockwise int8 quantization                                                  #
# --------------------------------------------------------------------------- #
class Quantized(NamedTuple):
    q: jnp.ndarray  # int8, original shape
    scale: jnp.ndarray  # f32, (*leading_dims, ceil(last/QBLOCK))


def quantize_blockwise(x: jnp.ndarray) -> Quantized:
    """Blockwise int8 along the LAST axis only.

    Blocking the last axis (instead of a global flatten) keeps every
    leading dim — and therefore the tensor's SPMD sharding — intact; a
    flatten/reshape across sharded dims forces XLA to re-gather the full
    tensor per device (measured as multi-TB temps on the 1T-param config).
    """
    x32 = x.astype(jnp.float32)
    shape = x32.shape
    last = shape[-1] if shape else 1
    flat = x32.reshape(*shape[:-1], last) if shape else x32.reshape(1)
    pad = (-last) % QBLOCK
    if pad:
        pad_widths = [(0, 0)] * (len(shape) - 1) + [(0, pad)]
        flat = jnp.pad(flat, pad_widths)
    nb = (last + pad) // QBLOCK
    blocks = flat.reshape(*shape[:-1], nb, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0  # (*lead, nb)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(*shape[:-1], last + pad)[..., :last]
    return Quantized(q=q, scale=scale)


def dequantize_blockwise(qx: Quantized, shape) -> jnp.ndarray:
    shape = tuple(shape)
    last = shape[-1] if shape else 1
    pad = (-last) % QBLOCK
    flat = qx.q.astype(jnp.float32)
    if pad:
        pad_widths = [(0, 0)] * (len(shape) - 1) + [(0, pad)]
        flat = jnp.pad(flat, pad_widths)
    nb = (last + pad) // QBLOCK
    blocks = flat.reshape(*shape[:-1], nb, QBLOCK)
    safe = jnp.where(qx.scale > 0, qx.scale, 1.0)
    out = blocks * safe[..., None]
    return out.reshape(*shape[:-1], last + pad)[..., :last]


# --------------------------------------------------------------------------- #
# Schedules                                                                    #
# --------------------------------------------------------------------------- #
def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def constant_lr(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


# --------------------------------------------------------------------------- #
# Grad utilities                                                               #
# --------------------------------------------------------------------------- #
def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * factor).astype(x.dtype), tree), norm


# --------------------------------------------------------------------------- #
# AdamW                                                                        #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: float | None = 1.0
    moment_dtype: str = "float32"  # float32 | int8

    def lr_fn(self):
        return self.lr if callable(self.lr) else constant_lr(self.lr)


def adamw_init(params, cfg: AdamWConfig):
    def zero_moment(p):
        if cfg.moment_dtype == "int8":
            return quantize_blockwise(jnp.zeros(p.shape, jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zero_moment, params),
        "v": jax.tree.map(zero_moment, params),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr_fn()(step)
    gnorm = global_norm(grads)
    if cfg.max_grad_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.max_grad_norm)

    quantized = cfg.moment_dtype == "int8"

    def leaf_update(p, g, m, v):
        g32 = g.astype(jnp.float32)
        if quantized:
            m32 = dequantize_blockwise(m, p.shape)
            v32 = dequantize_blockwise(v, p.shape)
        else:
            m32, v32 = m, v
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if quantized:
            return new_p, quantize_blockwise(m32), quantize_blockwise(v32)
        return new_p, m32, v32

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    is_q = lambda x: isinstance(x, Quantized)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    out = [leaf_update(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------------- #
# SGD (momentum)                                                               #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: Callable | float = 1e-2
    momentum: float = 0.9
    max_grad_norm: float | None = None

    def lr_fn(self):
        return self.lr if callable(self.lr) else constant_lr(self.lr)


def sgd_init(params, cfg: SGDConfig):
    return {
        "step": jnp.zeros((), jnp.int32),
        "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def sgd_update(grads, state, params, cfg: SGDConfig):
    step = state["step"] + 1
    lr = cfg.lr_fn()(step)
    gnorm = global_norm(grads)
    if cfg.max_grad_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.max_grad_norm)

    def leaf(p, g, mom):
        mom = cfg.momentum * mom + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * mom).astype(p.dtype), mom

    flat = jax.tree.map(leaf, params, grads, state["mom"])
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mom = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "mom": new_mom}, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------------- #
# Optimizer facade                                                             #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def make_adamw(cfg: AdamWConfig = AdamWConfig()) -> Optimizer:
    return Optimizer(
        init=lambda p: adamw_init(p, cfg),
        update=lambda g, s, p: adamw_update(g, s, p, cfg),
    )


def make_sgd(cfg: SGDConfig = SGDConfig()) -> Optimizer:
    return Optimizer(
        init=lambda p: sgd_init(p, cfg),
        update=lambda g, s, p: sgd_update(g, s, p, cfg),
    )
