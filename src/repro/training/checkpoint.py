"""Checkpointing: atomic, sharding-aware, async, elastic.

Layout per step::

    <dir>/step_<n>/
        manifest.json   — pytree structure, shapes, dtypes, mesh/sharding
                          metadata, framework version, user metadata
        arrays.npz      — flattened leaves keyed by escaped tree path
        _COMPLETE       — commit marker (written last; readers ignore
                          directories without it → crash-safe)

Features:
* atomic publish (write to ``.tmp-`` dir, fsync, rename, marker),
* retention (keep_last),
* async save on a background thread (``save_async`` returns a handle;
  ``wait()`` joins — training overlaps checkpoint I/O with compute),
* **elastic restore**: ``restore(..., sharding_fn=...)`` re-places every
  leaf with a caller-supplied sharding for the *current* mesh, so a job
  restarted on a different topology (e.g. 256 → 512 chips) resumes from the
  same artifact — the paper-scale fault-tolerance requirement.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

_MARKER = "_COMPLETE"


def _escape(path_parts) -> str:
    return "/".join(str(p) for p in path_parts)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for keypath, leaf in flat:
        parts = []
        for k in keypath:
            if hasattr(k, "key"):
                parts.append(k.key)
            elif hasattr(k, "idx"):
                parts.append(k.idx)
            else:
                parts.append(str(k))
        out[_escape(parts)] = leaf
    return out, treedef


@dataclasses.dataclass
class SaveHandle:
    thread: threading.Thread | None
    path: str

    def wait(self):
        if self.thread is not None:
            self.thread.join()


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    # -- paths -----------------------------------------------------------------
    def step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def available_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, _MARKER)):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.available_steps()
        return steps[-1] if steps else None

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree, *, metadata: dict | None = None) -> str:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._save_host(step, host_tree, metadata or {})

    def save_async(self, step: int, tree, *, metadata: dict | None = None) -> SaveHandle:
        # device→host copy happens synchronously (consistent snapshot);
        # serialization + fsync on the background thread.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        path = self.step_dir(step)
        t = threading.Thread(
            target=self._save_host, args=(step, host_tree, metadata or {}), daemon=True
        )
        t.start()
        return SaveHandle(thread=t, path=path)

    def _save_host(self, step: int, host_tree, metadata: dict) -> str:
        final = self.step_dir(step)
        tmp = final + f".tmp-{os.getpid()}-{threading.get_ident()}"
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _flatten_with_paths(host_tree)
        arrays = {}
        spec = {}
        for key, leaf in leaves.items():
            arr = np.asarray(leaf)
            # npz keys cannot contain '/': escape
            arrays[key.replace("/", "|")] = arr
            spec[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "created": time.time(),
            "leaves": spec,
            "metadata": metadata,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, _MARKER), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._apply_retention()
        return final

    def _apply_retention(self):
        steps = self.available_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(
        self,
        like,
        *,
        step: int | None = None,
        sharding_fn: Callable[[str, Any], Any] | None = None,
    ):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``sharding_fn(path, leaf_spec) → Sharding`` if
        given re-places each leaf for the current mesh (elastic restart).
        Returns (tree, manifest)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no complete checkpoints in {self.directory}")
        d = self.step_dir(step)
        if not os.path.exists(os.path.join(d, _MARKER)):
            raise FileNotFoundError(f"checkpoint step {step} incomplete")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        like_leaves, treedef = _flatten_with_paths(like)
        out = {}
        for key, leaf in like_leaves.items():
            npz_key = key.replace("/", "|")
            if npz_key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[npz_key]
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"leaf {key}: checkpoint {arr.shape} != expected {want_shape}")
            want_dtype = leaf.dtype
            arr = arr.astype(want_dtype)
            if sharding_fn is not None:
                out[key] = jax.device_put(arr, sharding_fn(key, leaf))
            else:
                out[key] = jnp.asarray(arr)
        ordered = [out[k] for k in like_leaves.keys()]
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest
