"""Training substrate: optimizers, train loop, checkpointing, fault
tolerance, gradient compression, data pipeline."""

from repro.training.checkpoint import CheckpointManager
from repro.training.compression import Int8Compressor, TopKCompressor, compressed_psum
from repro.training.data import LMDataConfig, Prefetcher, TokenStream, pack_documents
from repro.training.fault_tolerance import (
    HeartbeatMonitor,
    RestartSupervisor,
    StragglerDetector,
    TrainingFailure,
)
from repro.training.optimizer import (
    AdamWConfig,
    SGDConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    make_adamw,
    make_sgd,
    warmup_cosine,
)
from repro.training.train_loop import TrainStepConfig, make_train_step, microbatch
