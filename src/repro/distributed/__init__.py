"""Distribution utilities: mesh construction, partition specs, collectives."""
from repro.distributed.mesh_utils import (
    corpus_mesh,
    make_mesh,
    mesh_device_count,
    named_sharding,
    shard_map_compat,
)
from repro.distributed.partition import ShardingPolicy
