"""Distribution utilities: mesh construction, partition specs, collectives."""
from repro.distributed.mesh_utils import (
    make_mesh,
    mesh_device_count,
    named_sharding,
    shard_map_compat,
)
