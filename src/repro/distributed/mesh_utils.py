"""Mesh construction helpers shared by launch/ and tests.

``jax.make_mesh`` defaults will flip axis_types to Explicit in jax 0.9; we
pin Auto explicitly so pjit/shard_map semantics stay stable across versions.
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import AxisType, Mesh, NamedSharding, PartitionSpec


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes))


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec(*spec))


def mesh_device_count(mesh: Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
