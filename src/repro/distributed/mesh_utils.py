"""Mesh construction helpers shared by launch/ and tests.

``jax.make_mesh`` defaults will flip axis_types to Explicit in jax 0.9; we
pin Auto explicitly so pjit/shard_map semantics stay stable across versions.
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:  # AxisType landed after 0.4.x; older jax is implicitly Auto everywhere
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes))


def corpus_mesh(n_shards: int, axis: str = "data") -> Mesh:
    """1-axis mesh over the first ``n_shards`` devices for corpus-row
    sharding (``ShardingPolicy.corpus_rows`` layout).

    Unlike :func:`make_mesh` (which always spans every device), this takes a
    device *subset* so an S-way sharded retrieval backend can coexist with
    other work on the remaining devices — and so S < device_count is
    expressible at all. Raises with the remediation (``XLA_FLAGS=
    --xla_force_host_platform_device_count=N`` for CPU hosts) when the host
    has too few devices.
    """
    import numpy as np

    devices = jax.devices()
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > len(devices):
        raise ValueError(
            f"n_shards={n_shards} > visible devices ({len(devices)}); on CPU "
            "hosts set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_shards} before importing jax, or use execution='threads'"
        )
    if AxisType is None:
        return Mesh(np.asarray(devices[:n_shards]), (axis,))
    return Mesh(np.asarray(devices[:n_shards]), (axis,), axis_types=(AxisType.Auto,))


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across the rename: new jax exposes it top-level with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map`` with the
    ``check_rep`` spelling of the same knob."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec(*spec))


def mesh_device_count(mesh: Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
