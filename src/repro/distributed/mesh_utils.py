"""Mesh construction helpers shared by launch/ and tests.

``jax.make_mesh`` defaults will flip axis_types to Explicit in jax 0.9; we
pin Auto explicitly so pjit/shard_map semantics stay stable across versions.
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:  # AxisType landed after 0.4.x; older jax is implicitly Auto everywhere
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes))


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across the rename: new jax exposes it top-level with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map`` with the
    ``check_rep`` spelling of the same knob."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec(*spec))


def mesh_device_count(mesh: Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
