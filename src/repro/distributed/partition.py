"""Partition specs: how every tensor in the system shards over the mesh.

Mesh axes (launch/mesh.py): ``("data", "model")`` single-pod,
``("pod", "data", "model")`` multi-pod. Policy:

* **DP**   — batch over ``(pod, data)``.
* **TP**   — attention heads / FFN hidden / vocab over ``model``.
* **EP**   — MoE experts over ``model``; dispatch capacity over ``data``.
* **SP**   — KV-cache *sequence* over ``model`` (flash-decoding with
  distributed LSE — decode attention reduces over the sharded seq axis and
  XLA inserts the LSE-style all-reduce). This is what makes 32k×128 and
  524k×1 caches fit per-chip HBM; see DESIGN.md §4.
* **ZeRO** — optimizer moments additionally sharded over ``data`` on the
  largest evenly-divisible dim (``zero_shard``).

Everything is expressed as ``PartitionSpec`` factories parameterized by the
axis names actually present, so the same policy serves both meshes.
"""

from __future__ import annotations

import dataclasses

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Axis-name bundle + spec factories for the LM family."""

    data_axes: tuple[str, ...] = ("data",)  # ("pod","data") on multi-pod
    model_axis: str | None = "model"
    shard_kv_seq: bool = True  # SP for KV caches (decode)

    # -- helpers -------------------------------------------------------------
    @property
    def dp(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    @property
    def tp(self):
        return self.model_axis

    # -- LM params (stacked layers: leading dim L) -----------------------------
    def embed(self) -> P:
        return P(self.tp, None)  # (V, d): vocab over model

    def lm_head(self) -> P:
        return P(None, self.tp)  # (d, V)

    def attn_in(self) -> P:
        return P(None, None, self.tp)  # (L, d, H*dh): heads over model

    def attn_out(self) -> P:
        return P(None, self.tp, None)  # (L, H*dh, d)

    def ffn_in(self) -> P:
        return P(None, None, self.tp)  # (L, d, ff)

    def ffn_out(self) -> P:
        return P(None, self.tp, None)  # (L, ff, d)

    def norm(self) -> P:
        return P(None, None)  # (L, d) replicated

    def moe_router(self) -> P:
        return P(None, None, None)  # (L, d, E): replicated (tiny)

    def moe_expert_in(self) -> P:
        return P(None, self.tp, None, None)  # (L, E, d, ff): EP

    def moe_expert_out(self) -> P:
        return P(None, self.tp, None, None)  # (L, E, ff, d): EP

    # -- activations ------------------------------------------------------------
    def tokens(self) -> P:
        return P(self.dp, None)  # (B, S)

    def activations(self) -> P:
        return P(self.dp, None, None)  # (B, S, d)

    def logits(self) -> P:
        return P(self.dp, None, self.tp)  # (B, S, V)

    def moe_dispatch(self) -> P:
        # (E, C, d): experts over model, capacity over data
        return P(self.tp, self.dp, None)

    # -- KV cache (L, B, S, Hk, dh) ----------------------------------------------
    def kv_cache(self) -> P:
        seq = self.tp if self.shard_kv_seq else None
        return P(None, self.dp, seq, None, None)

    def kv_lengths(self) -> P:
        return P(self.dp)

    # -- retrieval corpus (N, d) --------------------------------------------
    def corpus_rows(self) -> P:
        """Retrieval corpus embeddings: rows over the data axes, dims
        replicated — the layout both ``DenseIndex.sharded_search_fn`` and
        the host-level ``retrieval/sharded.py`` backend partition by, so
        one mesh serves model shards and corpus shards consistently."""
        return P(self.dp, None)


def zero_shard(spec: P, shape: tuple[int, ...], data_axes: tuple[str, ...], axis_sizes: dict[str, int]) -> P:
    """ZeRO-style moment sharding: add the data axes to the first unsharded
    dim whose size divides the data world; fall back to ``spec`` unchanged.
    """
    world = 1
    for a in data_axes:
        world *= axis_sizes[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, entry) in enumerate(zip(shape, entries)):
        if entry is None and dim % world == 0 and dim > 0:
            entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*entries)
    return spec


def spec_for_path(path: str, policy: ShardingPolicy) -> P:
    """Map a param pytree path (joined by '/') to its PartitionSpec."""
    leaf = path.split("/")[-1]
    table = {
        "embed": policy.embed(),
        "lm_head": policy.lm_head(),
        "wq": policy.attn_in(),
        "wk": policy.attn_in(),
        "wv": policy.attn_in(),
        "wo": policy.attn_out(),
        "w_gate": policy.ffn_in(),
        "w_up": policy.ffn_in(),
        "w_down": policy.ffn_out(),
        "router": policy.moe_router(),
        "e_gate": policy.moe_expert_in(),
        "e_up": policy.moe_expert_in(),
        "e_down": policy.moe_expert_out(),
        "scale": policy.norm(),
        "final_scale": P(None),
    }
    return table.get(leaf, P())
