"""Operational guardrails (paper §VII.C, §VIII.B, §VIII.E).

The paper motivates three guardrails from its failure-mode analysis:

* **Low-confidence fallback** (§VII.C): when retrieval confidence is below a
  threshold the corpus likely lacks coverage — "low retrieval confidence
  could trigger a fallback to direct_llm rather than generating a
  poorly-grounded answer from low-quality context".
* **Max-context-token guardrail** (§VIII.B): cap injected context tokens so
  no query incurs a catastrophic cost overrun.
* **Cost ceiling** (§VIII.D adjacent): hard per-query billed-token budget —
  demote to the deepest bundle whose cost prior fits.

These post-process routing decisions / retrieval outputs; they never modify
the utility function itself, keeping the routing math auditable.
"""

from __future__ import annotations

import dataclasses

from repro.core.bundles import BundleCatalog


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    min_retrieval_confidence: float = 0.0  # 0 disables the fallback
    max_context_tokens: int | None = None
    max_cost_tokens: int | None = None
    fallback_bundle: str = "direct_llm"


@dataclasses.dataclass(frozen=True)
class GuardrailOutcome:
    bundle_index: int
    demoted: bool
    reason: str | None


class Guardrails:
    def __init__(self, catalog: BundleCatalog, config: GuardrailConfig = GuardrailConfig()):
        self.catalog = catalog
        self.config = config
        self._fallback_idx = catalog.index_of(config.fallback_bundle)

    def pre_execution(self, bundle_index: int) -> GuardrailOutcome:
        """Cost-ceiling demotion before any tokens are spent."""
        cfg = self.config
        if cfg.max_cost_tokens is not None:
            b = self.catalog[bundle_index]
            if b.cost_prior_tokens > cfg.max_cost_tokens:
                # Demote to the deepest bundle whose cost prior fits.
                best, best_k = None, -1
                for i, cand in enumerate(self.catalog):
                    if cand.cost_prior_tokens <= cfg.max_cost_tokens and cand.top_k > best_k:
                        best, best_k = i, cand.top_k
                if best is None:
                    best = self._fallback_idx
                if best != bundle_index:
                    return GuardrailOutcome(best, True, "cost_ceiling")
        return GuardrailOutcome(bundle_index, False, None)

    def post_retrieval(
        self, bundle_index: int, retrieval_confidence: float
    ) -> GuardrailOutcome:
        """Low-confidence fallback after retrieval, before generation."""
        cfg = self.config
        b = self.catalog[bundle_index]
        if (
            not b.skip_retrieval
            and cfg.min_retrieval_confidence > 0.0
            and retrieval_confidence < cfg.min_retrieval_confidence
        ):
            return GuardrailOutcome(self._fallback_idx, True, "low_retrieval_confidence")
        return GuardrailOutcome(bundle_index, False, None)

    def clamp_context(self, context_token_count: int) -> int:
        """Max-context guardrail: how many context tokens may be injected."""
        if self.config.max_context_tokens is None:
            return context_token_count
        return min(context_token_count, self.config.max_context_tokens)
