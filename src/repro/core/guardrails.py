"""Operational guardrails (paper §VII.C, §VIII.B, §VIII.E).

The paper motivates three guardrails from its failure-mode analysis:

* **Low-confidence fallback** (§VII.C): when retrieval confidence is below a
  threshold the corpus likely lacks coverage — "low retrieval confidence
  could trigger a fallback to direct_llm rather than generating a
  poorly-grounded answer from low-quality context".
* **Max-context-token guardrail** (§VIII.B): cap injected context tokens so
  no query incurs a catastrophic cost overrun.
* **Cost ceiling** (§VIII.D adjacent): hard per-query billed-token budget —
  demote to the deepest bundle whose cost prior fits.

These post-process routing decisions / retrieval outputs; they never modify
the utility function itself, keeping the routing math auditable.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.bundles import BundleCatalog


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    min_retrieval_confidence: float = 0.0  # 0 disables the fallback
    max_context_tokens: int | None = None
    max_cost_tokens: int | None = None
    fallback_bundle: str = "direct_llm"
    # Per-backend low-confidence thresholds, overriding the global value for
    # bundles routed through that backend. Confidence *units differ per
    # backend* (cosine for dense/IVF/hybrid, raw unbounded BM25 for bm25 —
    # docs/retrieval.md#caveats), so one global threshold cannot be
    # meaningful across a mixed-backend catalog: set e.g.
    # ``{"bm25": 2.5}`` to guard lexical bundles on their own scale. An
    # entry of 0.0 disables the guardrail for that backend.
    min_retrieval_confidence_by_backend: Mapping[str, float] | None = None


@dataclasses.dataclass(frozen=True)
class GuardrailOutcome:
    bundle_index: int
    demoted: bool
    reason: str | None


class Guardrails:
    def __init__(self, catalog: BundleCatalog, config: GuardrailConfig = GuardrailConfig()):
        self.catalog = catalog
        self.config = config
        self._fallback_idx = catalog.index_of(config.fallback_bundle)

    def pre_execution(self, bundle_index: int) -> GuardrailOutcome:
        """Cost-ceiling demotion before any tokens are spent."""
        cfg = self.config
        if cfg.max_cost_tokens is not None:
            b = self.catalog[bundle_index]
            if b.cost_prior_tokens > cfg.max_cost_tokens:
                # Demote to the deepest bundle whose cost prior fits.
                best, best_k = None, -1
                for i, cand in enumerate(self.catalog):
                    if cand.cost_prior_tokens <= cfg.max_cost_tokens and cand.top_k > best_k:
                        best, best_k = i, cand.top_k
                if best is None:
                    best = self._fallback_idx
                if best != bundle_index:
                    return GuardrailOutcome(best, True, "cost_ceiling")
        return GuardrailOutcome(bundle_index, False, None)

    def confidence_threshold(self, backend: str) -> float:
        """The low-confidence threshold for bundles on ``backend`` — the
        per-backend override when configured, the global value otherwise."""
        by_backend = self.config.min_retrieval_confidence_by_backend
        if by_backend is not None and backend in by_backend:
            return float(by_backend[backend])
        return self.config.min_retrieval_confidence

    def post_retrieval(
        self, bundle_index: int, retrieval_confidence: float
    ) -> GuardrailOutcome:
        """Low-confidence fallback after retrieval, before generation.

        The threshold is resolved per backend (see
        :meth:`confidence_threshold`): retrieval confidence is the top hit's
        score, whose scale is backend-specific, so a mixed-backend catalog
        guards each backend on its own scale.
        """
        cfg = self.config
        b = self.catalog[bundle_index]
        threshold = self.confidence_threshold(b.backend)
        if (
            not b.skip_retrieval
            and threshold > 0.0
            and retrieval_confidence < threshold
        ):
            return GuardrailOutcome(self._fallback_idx, True, "low_retrieval_confidence")
        return GuardrailOutcome(bundle_index, False, None)

    def clamp_context(self, context_token_count: int) -> int:
        """Max-context guardrail: how many context tokens may be injected."""
        if self.config.max_context_tokens is None:
            return context_token_count
        return min(context_token_count, self.config.max_context_tokens)
