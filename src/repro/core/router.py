"""Per-query bundle selection (paper §IV, Appendix A).

The router consumes query signals and a bundle catalog and emits a
retrieval–generation specification per query: ``b* = argmax_b U_b(q)``,
optionally ε-greedy (Appendix A step 3; the paper's benchmark disables
exploration, §II.D).

Two call paths:

* :meth:`Router.route_batch_arrays` — the device path. Pure jnp over a
  complexity vector; jit-compatible; used inside the serving engine so whole
  request batches route on-device with no host round-trip.
* :meth:`Router.route` — the host path. Takes strings, extracts signals,
  returns :class:`RoutingDecision` records with full per-bundle utility
  breakdowns for auditability (paper §IV: "routing decisions auditable and
  reproducible at the query level").
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bundles import Bundle, BundleCatalog, DEFAULT_CATALOG
from repro.core.signals import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    DEFAULT_K_MAX,
    DEFAULT_L_MAX,
    batch_complexity,
    extract_signal_matrix,
)
from repro.core.utility import (
    DEFAULT_C0,
    DEFAULT_C1,
    DEFAULT_DELTA,
    DEFAULT_GAMMA,
    DEFAULT_GLOBAL_DECAY,
    DEFAULT_WEIGHTS,
    UtilityWeights,
    selection_utilities,
    selection_utilities_np,
)


@dataclasses.dataclass(frozen=True)
class RoutingDecision:
    """Auditable per-query routing record (paper §IV.A)."""

    query: str
    bundle: Bundle
    bundle_index: int
    complexity: float
    utilities: Mapping[str, float]  # bundle name → U_b
    explored: bool = False

    @property
    def selection_utility(self) -> float:
        return self.utilities[self.bundle.name]


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """All scalar knobs of the routing layer in one place."""

    weights: UtilityWeights = DEFAULT_WEIGHTS
    gamma: float = DEFAULT_GAMMA
    c0: float = DEFAULT_C0
    delta: float = DEFAULT_DELTA
    c1: float = DEFAULT_C1
    global_decay: float = DEFAULT_GLOBAL_DECAY
    alpha: float = DEFAULT_ALPHA
    beta: float = DEFAULT_BETA
    l_max: float = DEFAULT_L_MAX
    k_max: float = DEFAULT_K_MAX
    epsilon: float = 0.0  # exploration; 0 in the paper's benchmark


class Router:
    """Discrete utility-maximizing router over a bundle catalog."""

    def __init__(
        self,
        catalog: BundleCatalog = DEFAULT_CATALOG,
        config: RouterConfig = RouterConfig(),
    ):
        self.catalog = catalog
        self.config = config
        self._arrays = catalog.as_arrays()
        self._arrays_np = {k: np.asarray(v) for k, v in self._arrays.items()}

    # ------------------------------------------------------------------ #
    # Device path                                                         #
    # ------------------------------------------------------------------ #
    def complexity_batch(self, queries: Sequence[str]) -> jnp.ndarray:
        """Signals → complexity ``(N,)`` for a query batch.

        One vectorized pass shared by :meth:`route` and the serving engine's
        batched fast path — both paths score complexity through the same ops,
        so per-query and batched complexities are bit-identical.
        """
        sig = extract_signal_matrix(queries)
        return batch_complexity(
            sig,
            alpha=self.config.alpha,
            beta=self.config.beta,
            l_max=self.config.l_max,
            k_max=self.config.k_max,
        )

    def utilities_from_complexity(
        self,
        complexity: jnp.ndarray,
        *,
        latency_override: jnp.ndarray | None = None,
        cost_override: jnp.ndarray | None = None,
        recall_override: jnp.ndarray | None = None,
    ) -> jnp.ndarray:
        """Eq. 1 utilities ``(N, B)`` from a complexity vector ``(N,)``."""
        return selection_utilities(
            self._arrays,
            complexity,
            weights=self.config.weights,
            gamma=self.config.gamma,
            c0=self.config.c0,
            delta=self.config.delta,
            c1=self.config.c1,
            global_decay=self.config.global_decay,
            latency_override=latency_override,
            cost_override=cost_override,
            recall_override=recall_override,
        )

    def route_batch_arrays(
        self,
        complexity: jnp.ndarray,
        *,
        key: jax.Array | None = None,
        latency_override: jnp.ndarray | None = None,
        cost_override: jnp.ndarray | None = None,
        recall_override: jnp.ndarray | None = None,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Route a complexity batch → (bundle_idx ``(N,)`` i32, U ``(N,B)``).

        jit-compatible. Overrides may be ``(B,)`` (one refined prior vector
        for the whole batch) or ``(N, B)`` (per-query priors — the serving
        fast path routes a whole stream position-accurately in one call).
        With ``key`` and ``config.epsilon > 0``, applies ε-greedy
        exploration: with prob ε a uniform random bundle replaces the argmax
        (Appendix A step 3).
        """
        utilities = self.utilities_from_complexity(
            complexity,
            latency_override=latency_override,
            cost_override=cost_override,
            recall_override=recall_override,
        )
        choice = jnp.argmax(utilities, axis=-1).astype(jnp.int32)
        eps = self.config.epsilon
        if eps > 0.0:
            if key is None:
                raise ValueError("epsilon > 0 requires a PRNG key")
            k_explore, k_pick = jax.random.split(key)
            n, b = utilities.shape
            explore = jax.random.uniform(k_explore, (n,)) < eps
            random_pick = jax.random.randint(k_pick, (n,), 0, b, dtype=jnp.int32)
            choice = jnp.where(explore, random_pick, choice)
        return choice, utilities

    def route_batch_np(
        self,
        complexity: np.ndarray,
        *,
        latency_override: np.ndarray | None = None,
        cost_override: np.ndarray | None = None,
        recall_override: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Host mirror of :meth:`route_batch_arrays` (numpy, no device
        dispatch) — bit-identical utilities and choices; see
        :func:`~repro.core.utility.selection_utilities_np`.

        The serving fast path uses this for its exact position-by-position
        replay, where per-query device round-trips would dominate. Greedy
        only: exploration needs the device PRNG, so ``epsilon > 0`` raises
        (the engine never routes with exploration either way).
        """
        if self.config.epsilon > 0.0:
            raise ValueError("route_batch_np is greedy-only (epsilon > 0 unsupported)")
        utilities = self._utilities_np(
            complexity,
            latency_override=latency_override,
            cost_override=cost_override,
            recall_override=recall_override,
        )
        return utilities.argmax(axis=-1).astype(np.int32), utilities

    def _utilities_np(
        self,
        complexity: np.ndarray,
        *,
        latency_override: np.ndarray | None = None,
        cost_override: np.ndarray | None = None,
        recall_override: np.ndarray | None = None,
    ) -> np.ndarray:
        return selection_utilities_np(
            self._arrays_np,
            complexity,
            weights=self.config.weights,
            gamma=self.config.gamma,
            c0=self.config.c0,
            delta=self.config.delta,
            c1=self.config.c1,
            global_decay=self.config.global_decay,
            latency_override=latency_override,
            cost_override=cost_override,
            recall_override=recall_override,
        )

    # ------------------------------------------------------------------ #
    # Host path                                                           #
    # ------------------------------------------------------------------ #
    def route(
        self,
        queries: Sequence[str] | str,
        *,
        key: jax.Array | None = None,
        latency_override: np.ndarray | None = None,
        cost_override: np.ndarray | None = None,
        recall_override: np.ndarray | None = None,
    ) -> list[RoutingDecision]:
        """Route query strings; returns full audit records."""
        single = isinstance(queries, str)
        qs: Sequence[str] = [queries] if single else list(queries)
        cplx = self.complexity_batch(qs)
        idx, utilities = self.route_batch_arrays(
            cplx,
            key=key,
            latency_override=latency_override,
            cost_override=cost_override,
            recall_override=recall_override,
        )
        idx_np = np.asarray(idx)
        util_np = np.asarray(utilities)
        cplx_np = np.asarray(cplx)
        greedy = np.asarray(jnp.argmax(utilities, axis=-1))
        decisions = []
        for i, q in enumerate(qs):
            b_i = int(idx_np[i])
            decisions.append(
                RoutingDecision(
                    query=q,
                    bundle=self.catalog[b_i],
                    bundle_index=b_i,
                    complexity=float(cplx_np[i]),
                    utilities={
                        name: float(util_np[i, j]) for j, name in enumerate(self.catalog.names)
                    },
                    explored=bool(b_i != int(greedy[i])),
                )
            )
        return decisions

    def complexity_of(self, query: str) -> float:
        sig = extract_signal_matrix([query])
        return float(
            batch_complexity(
                sig,
                alpha=self.config.alpha,
                beta=self.config.beta,
                l_max=self.config.l_max,
                k_max=self.config.k_max,
            )[0]
        )


class FixedRouter(Router):
    """Degenerate router: always selects one bundle (the paper's fixed-*
    baselines, §VI.C). Utilities are still computed for telemetry parity."""

    def __init__(
        self,
        bundle_name: str,
        catalog: BundleCatalog = DEFAULT_CATALOG,
        config: RouterConfig = RouterConfig(),
    ):
        super().__init__(catalog, config)
        self.fixed_index = catalog.index_of(bundle_name)

    def route_batch_arrays(
        self, complexity, *, key=None, latency_override=None, cost_override=None,
        recall_override=None,
    ):
        utilities = self.utilities_from_complexity(
            complexity,
            latency_override=latency_override,
            cost_override=cost_override,
            recall_override=recall_override,
        )
        n = utilities.shape[0]
        return jnp.full((n,), self.fixed_index, dtype=jnp.int32), utilities

    def route_batch_np(
        self, complexity, *, latency_override=None, cost_override=None,
        recall_override=None,
    ):
        utilities = self._utilities_np(
            complexity,
            latency_override=latency_override,
            cost_override=cost_override,
            recall_override=recall_override,
        )
        n = utilities.shape[0]
        return np.full((n,), self.fixed_index, dtype=np.int32), utilities
