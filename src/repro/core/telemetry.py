"""Telemetry store and prior refinement (paper §IV.A step 6, §V.C, App. F).

Responsibilities:

* Log per-query execution records in the paper's CSV schema (Appendix F) —
  every figure/table benchmark in ``benchmarks/`` reads *only* these
  artifacts, mirroring the paper's "all results generated directly from
  logged CSV artifacts".
* Maintain per-bundle EMA estimates of observed latency and billed tokens.
  These feed back into utility estimation (§IV.A step 2: "using priors and
  optional telemetry"; corpus line 12: "Telemetry can refine latency and
  quality estimates per bundle after sufficient query volume").
"""

from __future__ import annotations

import csv
import dataclasses
import io
import json
import os
from typing import Iterable, Mapping

import numpy as np

from repro.core.bundles import BundleCatalog, DEFAULT_CATALOG

# Appendix F schema, in order.
CSV_FIELDS: tuple[str, ...] = (
    "query",
    "strategy",
    "bundle",
    "utility",
    "quality_proxy",
    "realized_utility",
    "latency",
    "prompt_tokens",
    "completion_tokens",
    "embedding_tokens",
    "retrieval_confidence",
    "complexity_score",
    "index_embedding_tokens",
)


@dataclasses.dataclass
class QueryRecord:
    """One executed query — the Appendix F row."""

    query: str
    strategy: str
    bundle: str
    utility: float
    quality_proxy: float
    realized_utility: float
    latency: float  # ms, end-to-end
    prompt_tokens: int
    completion_tokens: int
    embedding_tokens: int
    retrieval_confidence: float  # max cosine sim; NaN when retrieval skipped
    complexity_score: float
    index_embedding_tokens: int = 0  # offline bookkeeping (Eq. 2 note)
    # Resilience tagging (serving/resilience.py). Deliberately NOT in
    # CSV_FIELDS: the Appendix-F artifact schema is frozen, and a zero-fault
    # run must stay byte-identical — the tag lives on the record object and
    # in the resilience counters, not in the CSV.
    degraded: bool = False  # answered off-plan via the degradation ladder
    fallback_depth: int = 0  # ladder rungs walked to produce this answer

    @property
    def total_billed_tokens(self) -> int:
        """Eq. 2: τ_billed = τ_prompt + τ_completion + τ_embed."""
        return self.prompt_tokens + self.completion_tokens + self.embedding_tokens

    def as_csv_row(self) -> dict:
        d = dataclasses.asdict(self)
        return {k: d[k] for k in CSV_FIELDS}


@dataclasses.dataclass
class BundleStats:
    """Streaming per-bundle statistics with EMA refinement."""

    count: int = 0
    ema_latency_ms: float = float("nan")
    ema_cost_tokens: float = float("nan")
    ema_quality: float = float("nan")
    sum_latency: float = 0.0
    sum_cost: float = 0.0
    sum_quality: float = 0.0

    def update(self, latency_ms: float, cost_tokens: float, quality: float, ema_beta: float):
        if self.count == 0:
            self.ema_latency_ms = latency_ms
            self.ema_cost_tokens = cost_tokens
            self.ema_quality = quality
        else:
            b = ema_beta
            self.ema_latency_ms = b * self.ema_latency_ms + (1 - b) * latency_ms
            self.ema_cost_tokens = b * self.ema_cost_tokens + (1 - b) * cost_tokens
            self.ema_quality = b * self.ema_quality + (1 - b) * quality
        self.count += 1
        self.sum_latency += latency_ms
        self.sum_cost += cost_tokens
        self.sum_quality += quality


@dataclasses.dataclass
class RecallStats:
    """Running per-backend ``recall_vs_exact`` observations.

    One observation = one measured recall@k of a backend against exact
    retrieval over some query sample (``RAGEngine.calibrate_backend_recall``
    logs one per query). The refined recall prior shrinks toward the static
    curve until ``count`` clears the store's ``recall_min_samples``.
    """

    count: int = 0
    total: float = 0.0

    def update(self, recall: float) -> None:
        self.count += 1
        self.total += recall

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")


class TelemetryStore:
    """Accumulates QueryRecords; provides refined priors + CSV/JSON export.

    ``min_volume`` gates refinement ("after sufficient query volume"): until a
    bundle has that many observations, its static prior is used. ``blend``
    mixes prior and EMA so refinement is gradual and auditable.

    Beyond the latency/cost EMAs, the store also accumulates per-backend
    **recall calibration** observations (:meth:`observe_recall`): measured
    ``recall_vs_exact`` samples that refine each bundle's static backend
    recall prior (:meth:`refined_recall_priors`) once a backend clears
    ``recall_min_samples`` — the live counterpart of the static
    ``BackendCost.recall_prior`` curve (docs/retrieval.md#calibrating-recall-priors-from-telemetry).
    """

    def __init__(
        self,
        catalog: BundleCatalog = DEFAULT_CATALOG,
        *,
        ema_beta: float = 0.7,
        min_volume: int = 1,
        blend: float = 0.5,
        refine_latency: bool = True,
        refine_cost: bool = True,
        structural_latency: np.ndarray | None = None,
        structural_cost: np.ndarray | None = None,
        recall_min_samples: int = 8,
    ):
        self.catalog = catalog
        self.ema_beta = ema_beta
        self.min_volume = min_volume
        self.blend = blend
        self.refine_latency = refine_latency
        self.refine_cost = refine_cost
        # Per-bundle end-to-end predictions from the serving system's own
        # latency/billing models (observed units). Used as the estimate for
        # bundles telemetry hasn't sampled yet, and as the blend anchor.
        self.structural_latency = structural_latency
        self.structural_cost = structural_cost
        self.recall_min_samples = recall_min_samples
        self.records: list[QueryRecord] = []
        self.stats: dict[str, BundleStats] = {name: BundleStats() for name in catalog.names}
        self.recall_obs: dict[str, RecallStats] = {}

    # -- ingestion ----------------------------------------------------------
    def log(self, record: QueryRecord) -> None:
        self.records.append(record)
        # Degraded answers are forced, not routed: a fault pushed them onto
        # a fallback bundle, so their latency/cost say nothing about what
        # that bundle does under normal routing. They stay in the record
        # stream (auditable, counted) but never refine the EMA priors —
        # injected chaos must not corrupt routing.
        if record.degraded:
            return
        if record.strategy in self.stats:
            self.stats[record.strategy].update(
                record.latency,
                float(record.total_billed_tokens),
                record.quality_proxy,
                self.ema_beta,
            )

    def extend(self, records: Iterable[QueryRecord]) -> None:
        for r in records:
            self.log(r)

    def clone_for_replay(self) -> "TelemetryStore":
        """Lightweight copy for speculative what-if replay.

        Carries everything prior refinement reads — per-bundle stats,
        refinement knobs, structural anchors — but not the record history, so
        the serving pipeline's ``finalize`` stage (serving/stages.py) can
        simulate "what priors would query i have seen?" for a whole
        micro-batch without mutating (or deep-copying) the live store.
        Cloning at the finalize boundary — after every earlier micro-batch
        has committed — is what lets the N-deep stage pipeline route
        speculatively on stale priors and still commit position-exact
        records. Logging into the clone updates only the clone.
        """
        clone = TelemetryStore(
            self.catalog,
            ema_beta=self.ema_beta,
            min_volume=self.min_volume,
            blend=self.blend,
            refine_latency=self.refine_latency,
            refine_cost=self.refine_cost,
            structural_latency=self.structural_latency,
            structural_cost=self.structural_cost,
            recall_min_samples=self.recall_min_samples,
        )
        clone.stats = {name: dataclasses.replace(st) for name, st in self.stats.items()}
        clone.recall_obs = {
            name: dataclasses.replace(st) for name, st in self.recall_obs.items()
        }
        return clone

    # -- refined priors -------------------------------------------------------
    @property
    def refinement_active(self) -> bool:
        """True once >= 2 bundles have reached min_volume."""
        ready = sum(
            1 for st in self.stats.values()
            if st.count >= self.min_volume and np.isfinite(st.ema_latency_ms)
        )
        return ready >= 2

    def refined_latency_priors(self) -> np.ndarray:
        """Per-bundle latency estimates for Eq. 1 (consistent units).

        The static base is the *effective* (backend-scaled) prior, so a
        cheap lexical/approximate bundle keeps its latency edge until
        telemetry observes it (×1.0 for dense — bit-identical to the raw
        Table-I prior)."""
        priors = np.array(
            [self.catalog[n].effective_latency_prior_ms for n in self.catalog.names],
            np.float64,
        )
        if not self.refine_latency:
            return priors
        return self._refine(priors, attr="ema_latency_ms", structural=self.structural_latency)

    def refined_cost_priors(self) -> np.ndarray:
        priors = np.array(
            [self.catalog[n].cost_prior_tokens for n in self.catalog.names], np.float64
        )
        if not self.refine_cost:
            return priors
        return self._refine(priors, attr="ema_cost_tokens", structural=self.structural_cost)

    # -- recall calibration ---------------------------------------------------
    def observe_recall(self, backend: str, recall: float) -> None:
        """Log one measured ``recall_vs_exact`` observation for a backend.

        Observations come from explicit calibration passes (e.g.
        ``RAGEngine.calibrate_backend_recall`` comparing a backend's hits
        against the exact dense backend's), never from the serving hot path,
        so they are constant within any one micro-batch — which is why the
        finalize replay needs no recall staleness handling.
        """
        if not (0.0 <= recall <= 1.0):
            raise ValueError(f"recall must be in [0, 1], got {recall}")
        self.recall_obs.setdefault(backend, RecallStats()).update(float(recall))

    def refined_recall_priors(self) -> np.ndarray | None:
        """Per-bundle backend-recall priors refined from observations.

        Returns ``None`` when **no** backend has reached
        ``recall_min_samples`` — the common case, and the fast path that
        keeps unobserved catalogs (the paper's dense-only regime in
        particular) byte-identical: the routing layer then uses the static
        ``backend_recall`` column exactly as before.

        Otherwise returns a ``(B,)`` float64 vector where each bundle's
        entry is:

        * the **static** curve value (``bundle.backend_cost.recall_prior``)
          when its backend is below the min-sample threshold — the
          shrinkage guard: sparse, noisy recall samples must not move
          routing;
        * otherwise the shrinkage blend
          ``w·mean_observed + (1−w)·static`` with
          ``w = count / (count + recall_min_samples)`` — asymptotically
          trusting the measurements, never snapping to them.

        Dense bundles keep their exact static 1.0 unless someone explicitly
        observes "dense" (exact retrieval has nothing to calibrate), so the
        quality-prior multiply stays the exact identity the paper-catalog
        parity depends on.
        """
        n0 = self.recall_min_samples
        if not any(st.count >= n0 for st in self.recall_obs.values()):
            return None
        out = []
        for name in self.catalog.names:
            bundle = self.catalog[name]
            static = float(bundle.backend_cost.recall_prior)
            obs = self.recall_obs.get(bundle.backend)
            if obs is None or obs.count < n0:
                out.append(static)
                continue
            w = obs.count / (obs.count + n0)
            refined = w * obs.mean + (1.0 - w) * static
            out.append(min(max(refined, 1e-6), 1.0))
        return np.asarray(out, np.float64)

    def _refine(self, priors: np.ndarray, attr: str, structural: np.ndarray | None) -> np.ndarray:
        """Refinement in *observed* units (paper §IV.A step 2: "priors and
        optional telemetry").

        Selection priors (Table I) are naive model-scale estimates; observed
        EMAs are end-to-end. Eq. 1 normalizes across the catalog, so the
        refined vector only needs internally consistent units. Until >= 2
        bundles reach ``min_volume`` the static priors are used unchanged
        ("after sufficient query volume"). Afterwards, per bundle:

        * observed → its EMA;
        * unobserved, when the serving system supplied ``structural``
          end-to-end predictions (from its own latency/billing models) →
          the prediction;
        * unobserved otherwise → a linear fit of EMA vs. top_k over the
          observed retrieval bundles (>= 2 needed), else the prior mapped
          rank-preservingly onto the observed range;
        * then blend with the structural anchor (or mapped prior) by
          ``blend`` — 0 trusts observations fully.
        """
        names = self.catalog.names
        emas = np.array([getattr(self.stats[n], attr) for n in names], np.float64)
        counts = np.array([self.stats[n].count for n in names])
        top_k = np.array([self.catalog[n].top_k for n in names], np.float64)
        is_retrieval = np.array([not self.catalog[n].skip_retrieval for n in names])
        ready = (counts >= self.min_volume) & np.isfinite(emas)
        if ready.sum() < 2:
            return priors
        e_lo, e_hi = emas[ready].min(), emas[ready].max()
        p_lo, p_hi = priors.min(), priors.max()
        if p_hi - p_lo < 1e-9:
            return priors
        span = max(e_hi - e_lo, 1e-9)
        # full-catalog priors mapped into observed units (rank-preserving)
        p_scaled = e_lo + (priors - p_lo) / (p_hi - p_lo) * span
        anchor = np.asarray(structural, np.float64) if structural is not None else p_scaled

        estimate = np.where(ready, emas, anchor)
        if structural is None:
            fit_mask = ready & is_retrieval
            if fit_mask.sum() >= 2:
                b, a = np.polyfit(top_k[fit_mask], emas[fit_mask], 1)
                estimate = np.where((~ready) & is_retrieval, a + b * top_k, estimate)
        return self.blend * anchor + (1 - self.blend) * estimate

    # -- summaries ------------------------------------------------------------
    def strategy_counts(self) -> dict[str, int]:
        counts = {name: 0 for name in self.catalog.names}
        for r in self.records:
            counts[r.strategy] = counts.get(r.strategy, 0) + 1
        return counts

    def _field_values(self, field: str) -> np.ndarray:
        """Record field as a float vector; ``"cost"`` aliases total billed
        tokens (the Eq. 2 sum) for every aggregate below."""
        if field == "cost":
            return np.asarray([r.total_billed_tokens for r in self.records], np.float64)
        return np.asarray([getattr(r, field) for r in self.records], np.float64)

    def mean(self, field: str) -> float:
        if not self.records:
            return float("nan")
        return float(np.mean(self._field_values(field)))

    def percentile(self, field: str, q: float | Iterable[float]) -> float | np.ndarray:
        """Percentile(s) of a record field over the logged stream — the tail
        view the closed-loop serving benchmarks report (p50/p95 latency vs
        offered load). ``field`` accepts any QueryRecord numeric field or
        ``"cost"`` for total billed tokens."""
        if not self.records:
            qs = np.atleast_1d(np.asarray(q, np.float64))
            out = np.full(qs.shape, np.nan)
            return float(out[0]) if np.isscalar(q) else out
        out = np.percentile(self._field_values(field), q)
        return float(out) if np.isscalar(q) else np.asarray(out)

    def per_strategy_means(self) -> dict[str, dict[str, float]]:
        """Table VI: per-strategy mean ± std of cost / latency / utility."""
        out: dict[str, dict[str, float]] = {}
        for name in self.catalog.names:
            rows = [r for r in self.records if r.strategy == name]
            if not rows:
                continue
            costs = np.array([r.total_billed_tokens for r in rows], np.float64)
            lats = np.array([r.latency for r in rows], np.float64)
            utils = np.array([r.utility for r in rows], np.float64)
            quals = np.array([r.quality_proxy for r in rows], np.float64)
            out[name] = {
                "n": float(len(rows)),
                "mean_cost": float(costs.mean()),
                "std_cost": float(costs.std()),
                "mean_latency": float(lats.mean()),
                "std_latency": float(lats.std()),
                "mean_utility": float(utils.mean()),
                "std_utility": float(utils.std()),
                "mean_quality": float(quals.mean()),
            }
        return out

    def correlation_matrix(self) -> tuple[np.ndarray, list[str]]:
        """Table VII: Pearson correlations among cost/latency/U/complexity."""
        if len(self.records) < 2:
            raise ValueError("need >= 2 records for correlations")
        cols = {
            "cost": [r.total_billed_tokens for r in self.records],
            "lat.": [r.latency for r in self.records],
            "U": [r.utility for r in self.records],
            "cplx.": [r.complexity_score for r in self.records],
        }
        mat = np.corrcoef(np.array(list(cols.values()), np.float64))
        return mat, list(cols.keys())

    # -- export ---------------------------------------------------------------
    def to_csv(self, path: str | None = None) -> str:
        buf = io.StringIO()
        writer = csv.DictWriter(buf, fieldnames=list(CSV_FIELDS))
        writer.writeheader()
        for r in self.records:
            writer.writerow(r.as_csv_row())
        text = buf.getvalue()
        if path is not None:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(text)
            os.replace(tmp, path)  # atomic publish
        return text

    @staticmethod
    def read_csv(path: str) -> list[QueryRecord]:
        records = []
        with open(path) as f:
            for row in csv.DictReader(f):
                records.append(
                    QueryRecord(
                        query=row["query"],
                        strategy=row["strategy"],
                        bundle=row["bundle"],
                        utility=float(row["utility"]),
                        quality_proxy=float(row["quality_proxy"]),
                        realized_utility=float(row["realized_utility"]),
                        latency=float(row["latency"]),
                        prompt_tokens=int(row["prompt_tokens"]),
                        completion_tokens=int(row["completion_tokens"]),
                        embedding_tokens=int(row["embedding_tokens"]),
                        retrieval_confidence=float(row["retrieval_confidence"]),
                        complexity_score=float(row["complexity_score"]),
                        index_embedding_tokens=int(row.get("index_embedding_tokens", 0) or 0),
                    )
                )
        return records

    def summary_json(self) -> str:
        return json.dumps(
            {
                "n_queries": len(self.records),
                "strategy_counts": self.strategy_counts(),
                "mean_cost": self.mean("cost"),
                "mean_latency": self.mean("latency"),
                "mean_quality": self.mean("quality_proxy"),
                "mean_utility": self.mean("utility"),
            },
            indent=2,
        )
