"""The paper's seven evaluation policies (§VI.C) as a registry.

(i)   router_default            — weights (0.6, 0.2, 0.2)
(ii)  router_latency_sensitive  — w_L = 0.5
(iii) router_cost_sensitive     — w_C = 0.5
(iv)  fixed_direct / fixed_light / fixed_medium / fixed_heavy
"""

from __future__ import annotations

from typing import Callable

from repro.core.bundles import BundleCatalog, DEFAULT_CATALOG
from repro.core.router import FixedRouter, Router, RouterConfig
from repro.core.utility import (
    COST_SENSITIVE_WEIGHTS,
    DEFAULT_WEIGHTS,
    LATENCY_SENSITIVE_WEIGHTS,
)

PolicyFactory = Callable[[BundleCatalog, RouterConfig], Router]


def _router_with(weights) -> PolicyFactory:
    def make(catalog: BundleCatalog, config: RouterConfig) -> Router:
        import dataclasses

        return Router(catalog, dataclasses.replace(config, weights=weights))

    return make


def _fixed(bundle_name: str) -> PolicyFactory:
    def make(catalog: BundleCatalog, config: RouterConfig) -> Router:
        return FixedRouter(bundle_name, catalog, config)

    return make


POLICIES: dict[str, PolicyFactory] = {
    "router_default": _router_with(DEFAULT_WEIGHTS),
    "router_latency_sensitive": _router_with(LATENCY_SENSITIVE_WEIGHTS),
    "router_cost_sensitive": _router_with(COST_SENSITIVE_WEIGHTS),
    "fixed_direct": _fixed("direct_llm"),
    "fixed_light": _fixed("light_rag"),
    "fixed_medium": _fixed("medium_rag"),
    "fixed_heavy": _fixed("heavy_rag"),
}


def make_policy(
    name: str,
    catalog: BundleCatalog = DEFAULT_CATALOG,
    config: RouterConfig = RouterConfig(),
) -> Router:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; available: {sorted(POLICIES)}")
    return POLICIES[name](catalog, config)
