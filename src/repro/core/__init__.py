"""CA-RAG core: the paper's primary contribution — per-query utility routing.

Public surface:
  signals    — QuerySignals + heuristic complexity (Eq. §V.A)
  bundles    — strategy bundle catalog (Table I)
  utility    — Eq. 1 selection utility + realized utility
  router     — argmax routing, ε-greedy, fixed baselines
  telemetry  — Appendix-F CSV logging + EMA prior refinement
  policies   — the paper's seven evaluation policies
  guardrails — confidence fallback / context cap / cost ceiling (§VIII)
"""

from repro.core.bundles import Bundle, BundleCatalog, DEFAULT_CATALOG, GenerationSpec
from repro.core.guardrails import GuardrailConfig, Guardrails
from repro.core.policies import POLICIES, make_policy
from repro.core.router import FixedRouter, Router, RouterConfig, RoutingDecision
from repro.core.signals import QuerySignals, batch_complexity, complexity, extract_signals
from repro.core.telemetry import QueryRecord, TelemetryStore
from repro.core.utility import (
    COST_SENSITIVE_WEIGHTS,
    DEFAULT_WEIGHTS,
    LATENCY_SENSITIVE_WEIGHTS,
    RealizedNormalization,
    UtilityWeights,
    realized_utility,
    selection_utilities,
)

__all__ = [
    "Bundle", "BundleCatalog", "DEFAULT_CATALOG", "GenerationSpec",
    "GuardrailConfig", "Guardrails", "POLICIES", "make_policy",
    "FixedRouter", "Router", "RouterConfig", "RoutingDecision",
    "QuerySignals", "batch_complexity", "complexity", "extract_signals",
    "QueryRecord", "TelemetryStore",
    "COST_SENSITIVE_WEIGHTS", "DEFAULT_WEIGHTS", "LATENCY_SENSITIVE_WEIGHTS",
    "RealizedNormalization", "UtilityWeights", "realized_utility",
    "selection_utilities",
]
