"""Strategy bundle catalog (paper §V.B, Table I).

A *bundle* couples a retrieval depth (top-k, possibly zero = retrieval-free)
with a fixed generation profile and the priors the router's utility function
consumes: expected quality, expected latency, and expected total billed
tokens ("context token usage", §V.B).

The four paper bundles::

    bundle      k   skip  qual.prior  lat.prior(ms)
    direct_llm  0   yes   0.52        8
    light_rag   3   no    0.66        45
    medium_rag  5   no    0.74        60
    heavy_rag   10  no    0.82        95

All bundles share the paper's generation spec ``paper_gen``: 256 max output
tokens, temperature 0.

The catalog converts to a dict of jnp arrays (:meth:`BundleCatalog.as_arrays`)
so utility evaluation and routing vectorize over (queries × bundles) on
device.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Mapping, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GenerationSpec:
    """Fixed generation profile shared by all paper bundles (§V.B)."""

    max_output_tokens: int = 256
    temperature: float = 0.0
    name: str = "paper_gen"


@dataclasses.dataclass(frozen=True)
class Bundle:
    """One retrieval+generation strategy bundle.

    ``depth_affinity`` ∈ [-1, 1] positions the bundle on the shallow↔deep
    axis; the quality-prior modulation (utility.py) uses it so that complex
    queries favour deep bundles. It is a derived, catalog-relative quantity —
    ``BundleCatalog`` recomputes it from rank when not supplied.
    """

    name: str
    top_k: int
    skip_retrieval: bool
    quality_prior: float
    latency_prior_ms: float
    cost_prior_tokens: float
    generation: GenerationSpec = GenerationSpec()
    depth_affinity: float = 0.0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.skip_retrieval and self.top_k != 0:
            raise ValueError(f"skip_retrieval bundles must have top_k=0 ({self.name})")
        if not self.skip_retrieval and self.top_k == 0:
            raise ValueError(f"retrieval bundles must have top_k>0 ({self.name})")
        if not (0.0 <= self.quality_prior <= 1.0):
            raise ValueError(f"quality_prior must be in [0,1] ({self.name})")


def _paper_bundles() -> tuple[Bundle, ...]:
    """Table I, verbatim, plus cost priors.

    Table I does not print token priors; §V.B says priors "encode expected
    quality, latency, and context token usage". Cost priors below are the
    expected *billed* tokens per bundle (prompt + completion + query
    embedding) for the paper's benchmark regime and are consistent with the
    per-strategy means in Table VI.
    """
    gen = GenerationSpec()
    return (
        Bundle("direct_llm", 0, True, 0.52, 8.0, 190.0, gen, -1.0),
        Bundle("light_rag", 3, False, 0.66, 45.0, 215.0, gen, -0.45),
        Bundle("medium_rag", 5, False, 0.74, 60.0, 275.0, gen, 1.0 / 3.0),
        Bundle("heavy_rag", 10, False, 0.82, 95.0, 360.0, gen, 1.0),
    )


class BundleCatalog:
    """An ordered, immutable catalog of bundles with array views.

    The catalog is the unit the router maximizes over (paper §III:
    ``b* = argmax_{b in B} U_b(q)``). Bundle order is significant — array
    columns, CSV strategy indices and telemetry slots all follow it.
    """

    def __init__(self, bundles: Sequence[Bundle] | None = None):
        bundles = tuple(bundles) if bundles is not None else _paper_bundles()
        if len(bundles) == 0:
            raise ValueError("catalog must contain at least one bundle")
        names = [b.name for b in bundles]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate bundle names: {names}")
        self._bundles = bundles
        self._index = {b.name: i for i, b in enumerate(bundles)}

    # -- container protocol -------------------------------------------------
    def __len__(self) -> int:
        return len(self._bundles)

    def __iter__(self) -> Iterator[Bundle]:
        return iter(self._bundles)

    def __getitem__(self, key: int | str) -> Bundle:
        if isinstance(key, str):
            return self._bundles[self._index[key]]
        return self._bundles[key]

    def index_of(self, name: str) -> int:
        return self._index[name]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(b.name for b in self._bundles)

    # -- array views ---------------------------------------------------------
    def as_arrays(self) -> Mapping[str, jnp.ndarray]:
        """Catalog priors as a dict of f32 arrays, shape ``(n_bundles,)``.

        Keys: quality_prior, latency_prior_ms, cost_prior_tokens, top_k,
        skip_retrieval, depth_affinity.
        """
        b = self._bundles
        return {
            "quality_prior": jnp.array([x.quality_prior for x in b], jnp.float32),
            "latency_prior_ms": jnp.array([x.latency_prior_ms for x in b], jnp.float32),
            "cost_prior_tokens": jnp.array([x.cost_prior_tokens for x in b], jnp.float32),
            "top_k": jnp.array([x.top_k for x in b], jnp.int32),
            "skip_retrieval": jnp.array([x.skip_retrieval for x in b], jnp.bool_),
            "depth_affinity": jnp.array([x.depth_affinity for x in b], jnp.float32),
        }

    def with_bundle(self, bundle: Bundle) -> "BundleCatalog":
        """Extended catalog — the §VIII.F scalability pathway (new bundles
        compose without touching the routing API)."""
        return BundleCatalog(self._bundles + (bundle,))

    def __repr__(self) -> str:
        return f"BundleCatalog({', '.join(self.names)})"


DEFAULT_CATALOG = BundleCatalog()
