"""Strategy bundle catalog (paper §V.B, Table I).

A *bundle* couples a retrieval depth (top-k, possibly zero = retrieval-free)
with a fixed generation profile and the priors the router's utility function
consumes: expected quality, expected latency, and expected total billed
tokens ("context token usage", §V.B).

The four paper bundles::

    bundle      k   skip  qual.prior  lat.prior(ms)
    direct_llm  0   yes   0.52        8
    light_rag   3   no    0.66        45
    medium_rag  5   no    0.74        60
    heavy_rag   10  no    0.82        95

All bundles share the paper's generation spec ``paper_gen``: 256 max output
tokens, temperature 0.

The catalog converts to a dict of jnp arrays (:meth:`BundleCatalog.as_arrays`)
so utility evaluation and routing vectorize over (queries × bundles) on
device.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Mapping, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GenerationSpec:
    """Fixed generation profile shared by all paper bundles (§V.B)."""

    max_output_tokens: int = 256
    temperature: float = 0.0
    name: str = "paper_gen"


@dataclasses.dataclass(frozen=True)
class Bundle:
    """One retrieval+generation strategy bundle.

    ``depth_affinity`` ∈ [-1, 1] positions the bundle on the shallow↔deep
    axis; the quality-prior modulation (utility.py) uses it so that complex
    queries favour deep bundles. It is a derived, catalog-relative quantity —
    ``BundleCatalog`` recomputes it from rank when not supplied.

    ``backend`` names the retrieval method the bundle routes through
    (``retrieval/backend.py``); ``"dense"`` — exact MIPS — is the paper's
    regime and the default, so the Table-I catalog is unchanged. The
    backend's static :class:`~repro.retrieval.backend.BackendCost`
    descriptor scales the bundle's latency/quality priors (the
    ``effective_*`` properties), which is how the router discriminates a
    cheap lexical bundle from an exact dense one at the same depth.
    """

    name: str
    top_k: int
    skip_retrieval: bool
    quality_prior: float
    latency_prior_ms: float
    cost_prior_tokens: float
    generation: GenerationSpec = GenerationSpec()
    depth_affinity: float = 0.0
    backend: str = "dense"

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.skip_retrieval and self.top_k != 0:
            raise ValueError(f"skip_retrieval bundles must have top_k=0 ({self.name})")
        if not self.skip_retrieval and self.top_k == 0:
            raise ValueError(f"retrieval bundles must have top_k>0 ({self.name})")
        if not (0.0 <= self.quality_prior <= 1.0):
            raise ValueError(f"quality_prior must be in [0,1] ({self.name})")
        if not self.backend:
            raise ValueError(f"backend must be a non-empty name ({self.name})")

    @property
    def backend_cost(self):
        """Static cost descriptor of this bundle's retrieval backend."""
        from repro.retrieval.backend import backend_cost  # lazy: no core→retrieval cycle

        return backend_cost(self.backend)

    @property
    def effective_latency_prior_ms(self) -> float:
        """Latency prior scaled by the backend's retrieve-stage cost (×1.0
        for dense, so paper-catalog priors are bit-identical)."""
        return self.latency_prior_ms * self.backend_cost.latency_scale

    @property
    def effective_quality_prior(self) -> float:
        """Quality prior discounted by the backend's expected recall@k."""
        return self.quality_prior * self.backend_cost.recall_prior


def _paper_bundles() -> tuple[Bundle, ...]:
    """Table I, verbatim, plus cost priors.

    Table I does not print token priors; §V.B says priors "encode expected
    quality, latency, and context token usage". Cost priors below are the
    expected *billed* tokens per bundle (prompt + completion + query
    embedding) for the paper's benchmark regime and are consistent with the
    per-strategy means in Table VI.
    """
    gen = GenerationSpec()
    return (
        Bundle("direct_llm", 0, True, 0.52, 8.0, 190.0, gen, -1.0),
        Bundle("light_rag", 3, False, 0.66, 45.0, 215.0, gen, -0.45),
        Bundle("medium_rag", 5, False, 0.74, 60.0, 275.0, gen, 1.0 / 3.0),
        Bundle("heavy_rag", 10, False, 0.82, 95.0, 360.0, gen, 1.0),
    )


class BundleCatalog:
    """An ordered, immutable catalog of bundles with array views.

    The catalog is the unit the router maximizes over (paper §III:
    ``b* = argmax_{b in B} U_b(q)``). Bundle order is significant — array
    columns, CSV strategy indices and telemetry slots all follow it.
    """

    def __init__(self, bundles: Sequence[Bundle] | None = None):
        bundles = tuple(bundles) if bundles is not None else _paper_bundles()
        if len(bundles) == 0:
            raise ValueError("catalog must contain at least one bundle")
        names = [b.name for b in bundles]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate bundle names: {names}")
        self._bundles = bundles
        self._index = {b.name: i for i, b in enumerate(bundles)}

    # -- container protocol -------------------------------------------------
    def __len__(self) -> int:
        return len(self._bundles)

    def __iter__(self) -> Iterator[Bundle]:
        return iter(self._bundles)

    def __getitem__(self, key: int | str) -> Bundle:
        if isinstance(key, str):
            return self._bundles[self._index[key]]
        return self._bundles[key]

    def index_of(self, name: str) -> int:
        return self._index[name]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(b.name for b in self._bundles)

    # -- backend views --------------------------------------------------------
    @property
    def backend_names(self) -> tuple[str, ...]:
        """Per-bundle backend name, catalog order."""
        return tuple(b.backend for b in self._bundles)

    def backends_used(self) -> tuple[str, ...]:
        """Unique backends any retrieval bundle routes through (first-use
        order) — what an engine must construct to serve this catalog."""
        return tuple(
            dict.fromkeys(b.backend for b in self._bundles if not b.skip_retrieval)
        )

    def routed_by_backend(self, strategy_counts: Mapping[str, int]) -> dict[str, int]:
        """Aggregate per-bundle routed counts (``TelemetryStore.
        strategy_counts``) by retrieval backend, with skip-retrieval bundles
        under ``"no_retrieval"``. Sorted keys — the single (backend × depth)
        routing view the serve CLI prints and the catalog-comparison
        benchmark emits."""
        out: dict[str, int] = {}
        for name, n in strategy_counts.items():
            b = self[name]
            key = "no_retrieval" if b.skip_retrieval else b.backend
            out[key] = out.get(key, 0) + n
        return dict(sorted(out.items()))

    # -- array views ---------------------------------------------------------
    def as_arrays(self) -> Mapping[str, jnp.ndarray]:
        """Catalog priors as a dict of f32 arrays, shape ``(n_bundles,)``.

        Keys: quality_prior, latency_prior_ms, cost_prior_tokens, top_k,
        skip_retrieval, depth_affinity, backend_recall,
        backend_latency_scale.

        ``latency_prior_ms`` is the *effective* (backend-scaled) prior;
        ``backend_recall`` carries each bundle's backend recall prior for
        the utility function to fold into expected quality (utility.py).
        Both are exactly 1.0-scaled for dense bundles, so the paper
        catalog's arrays are bit-identical to the pre-backend ones.
        """
        b = self._bundles
        return {
            "quality_prior": jnp.array([x.quality_prior for x in b], jnp.float32),
            "latency_prior_ms": jnp.array(
                [x.effective_latency_prior_ms for x in b], jnp.float32
            ),
            "cost_prior_tokens": jnp.array([x.cost_prior_tokens for x in b], jnp.float32),
            "top_k": jnp.array([x.top_k for x in b], jnp.int32),
            "skip_retrieval": jnp.array([x.skip_retrieval for x in b], jnp.bool_),
            "depth_affinity": jnp.array([x.depth_affinity for x in b], jnp.float32),
            "backend_recall": jnp.array(
                [x.backend_cost.recall_prior for x in b], jnp.float32
            ),
            "backend_latency_scale": jnp.array(
                [x.backend_cost.latency_scale for x in b], jnp.float32
            ),
        }

    def with_bundle(self, bundle: Bundle) -> "BundleCatalog":
        """Extended catalog — the §VIII.F scalability pathway (new bundles
        compose without touching the routing API)."""
        return BundleCatalog(self._bundles + (bundle,))

    def __repr__(self) -> str:
        return f"BundleCatalog({', '.join(self.names)})"


def _extended_bundles() -> tuple[Bundle, ...]:
    """The backend-aware catalog: Table I plus three non-dense operating
    points — the cheap-lexical / approximate / fused regimes "Fast or
    Better?" (Su et al., 2025) shows matter for user-controlled
    cost-accuracy tradeoffs.

    * ``bm25_light`` — lexical top-3, no embed call at all: cheaper than
      ``light_rag`` on every axis. ``quality_prior`` is the expected
      quality *given a lexical hit*; the backend's recall prior (0.62)
      discounts it to ~0.58 effective in Eq. 1, and the strongly shallow
      affinity (−0.75) confines it to the simplest queries.
    * ``ivf_medium`` — approximate top-5 over the same vectors at roughly
      half the scoring cost; the IVF recall prior (0.81 at the default
      2/4 probe) is what the router trades against its latency edge over
      ``medium_rag``, and the mild affinity (0.15) slots it between the
      shallow and deep dense bands.
    * ``hybrid_heavy`` — dense+BM25 fusion at depth 10: the quality
      ceiling, priced above ``heavy_rag`` (two searches + fusion).

    Priors follow the Table-I convention (latency = model-scale ms before
    the backend scale; cost = expected billed tokens — note ``bm25_light``
    saves the ~7 embedding tokens grounded bundles bill). The values are
    calibrated so a ``router_default`` pass over the 28-query paper
    benchmark exercises all four backends (pinned by
    tests/test_backend.py); the complexity bands they induce survive
    telemetry refinement because the recall discount and affinity — not
    the static latency/cost priors refinement replaces — carry the
    discrimination.
    """
    gen = GenerationSpec()
    return _paper_bundles() + (
        Bundle("bm25_light", 3, False, 0.94, 45.0, 208.0, gen, -0.75, backend="bm25"),
        Bundle("ivf_medium", 5, False, 0.84, 60.0, 275.0, gen, 0.15, backend="ivf"),
        Bundle("hybrid_heavy", 10, False, 0.86, 100.0, 367.0, gen, 1.0, backend="hybrid"),
    )


CATALOG_PRESETS: tuple[str, ...] = ("paper", "extended")


def make_catalog(preset: str = "paper") -> BundleCatalog:
    """Catalog presets: ``paper`` (Table I, dense-only — the parity-pinned
    default) or ``extended`` (paper + BM25-light / IVF-medium /
    hybrid-heavy; the (backend × depth × generation) catalog)."""
    if preset == "paper":
        return BundleCatalog()
    if preset == "extended":
        return BundleCatalog(_extended_bundles())
    raise ValueError(f"unknown catalog preset {preset!r}; expected one of {CATALOG_PRESETS}")


DEFAULT_CATALOG = BundleCatalog()
