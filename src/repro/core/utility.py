"""Utility function and normalization (paper §V.C, Eq. 1).

Selection utility for bundle ``b`` on query ``q``::

    U_b = w_Q * Qhat_b(q) - w_L * Lhat_b_norm - w_C * Chat_b_norm     (Eq. 1)

where latency and cost estimates are min-max normalized to [0, 1] *across the
catalog*, and weights are operator-specified (default (0.6, 0.2, 0.2)).

Quality-prior modulation (§V.A: "Complexity modulates quality priors without
requiring an additional LLM call"). The paper does not print the modulation
form; we use a depth-affinity ramp::

    Qhat_b(q) = clip(prior_b + gamma * (c(q) - c0) * affinity_b, 0, 1)

so complex queries (c > c0) inflate deep bundles' expected quality and
deflate shallow ones', and vice versa for simple queries. gamma and c0 are
calibrated in configs/ca_rag_paper.py so the routed distribution matches the
paper's Fig. 1 split (see EXPERIMENTS.md).

After execution, the *realized* utility substitutes observed latency and
billed tokens into Eq. 1 (§V.C), normalized against the same catalog priors
so realized and selection utilities are comparable.

Everything here is pure jnp and vectorized over (n_queries, n_bundles).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class UtilityWeights:
    """Operator-specified objective weights (w_Q, w_L, w_C)."""

    quality: float = 0.6
    latency: float = 0.2
    cost: float = 0.2

    def as_tuple(self) -> tuple[float, float, float]:
        return (self.quality, self.latency, self.cost)


DEFAULT_WEIGHTS = UtilityWeights()
LATENCY_SENSITIVE_WEIGHTS = UtilityWeights(quality=0.6, latency=0.5, cost=0.2)
COST_SENSITIVE_WEIGHTS = UtilityWeights(quality=0.6, latency=0.2, cost=0.5)

# Default modulation constants; overridable per-experiment. Calibrated so the
# routed distribution over the paper's 28-query benchmark matches Fig. 1
# (see EXPERIMENTS.md §Calibration).
DEFAULT_GAMMA = 1.0
DEFAULT_C0 = 0.19
# Deep-escalation steepening: analytical prompts are "genuinely underserved
# by shallow retrieval" (§I), so deep bundles' quality prior rises
# super-linearly past c1 (weighted by clip(affinity,0,1)²).
DEFAULT_DELTA = 2.0
DEFAULT_C1 = 0.50
# Catalog-uniform quality decay with complexity: harder queries have lower
# expected answer quality for EVERY bundle (paper Fig. 6's right-skew — "a
# long tail of lower-utility queries corresponding to complex analytical
# prompts"; Table VI's heavy-mean U < direct-mean U). Being constant across
# bundles per query, this term NEVER changes the argmax — it only places the
# recorded utilities on the paper's scale.
DEFAULT_GLOBAL_DECAY = 1.5


def minmax_normalize(values: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Min-max normalize to [0,1] along ``axis``; constant rows map to 0.

    This is the catalog normalization of Eq. 1 — the *relative* position of a
    bundle's latency/cost among its peers is what is penalized.
    """
    values = jnp.asarray(values, jnp.float32)
    lo = jnp.min(values, axis=axis, keepdims=True)
    hi = jnp.max(values, axis=axis, keepdims=True)
    span = hi - lo
    safe = jnp.where(span > 0, span, 1.0)
    return jnp.where(span > 0, (values - lo) / safe, jnp.zeros_like(values))


def modulated_quality(
    quality_prior: jnp.ndarray,
    depth_affinity: jnp.ndarray,
    complexity: jnp.ndarray,
    *,
    gamma: float = DEFAULT_GAMMA,
    c0: float = DEFAULT_C0,
    delta: float = DEFAULT_DELTA,
    c1: float = DEFAULT_C1,
    global_decay: float = DEFAULT_GLOBAL_DECAY,
) -> jnp.ndarray:
    """Qhat_b(q): complexity-modulated quality prior.

    Linear ramp around c0 (shallow bundles lose / deep bundles gain quality
    as complexity rises) plus the deep-escalation hinge past c1 (deep-only,
    affinity-squared weighting). Shapes: quality_prior/depth_affinity
    ``(B,)``, complexity ``(N,)`` → returns ``(N, B)``.
    """
    c = jnp.asarray(complexity, jnp.float32)[..., None]  # (N, 1)
    q = jnp.asarray(quality_prior, jnp.float32)[None, :]  # (1, B)
    a = jnp.asarray(depth_affinity, jnp.float32)[None, :]
    deep = jnp.square(jnp.clip(a, 0.0, 1.0))
    hinge = jnp.maximum(c - c1, 0.0)
    decay = global_decay * jnp.maximum(c - c0, 0.0)  # bundle-uniform
    # Lower-bounded at 0 only: the estimated-quality axis is a *prior score*,
    # not a probability — capping it at 1 would make it impossible for any
    # deep bundle to overcome its (normalized-max) latency+cost penalty of
    # w_L + w_C, contradicting the paper's observed heavy_rag selections.
    # The uniform decay applies AFTER the floor so it shifts every bundle's
    # utility identically — the argmax (routing) is provably unaffected.
    return jnp.maximum(q + gamma * (c - c0) * a + delta * hinge * deep, 0.0) - decay


def selection_utilities(
    catalog_arrays: Mapping[str, jnp.ndarray],
    complexity: jnp.ndarray,
    *,
    weights: UtilityWeights = DEFAULT_WEIGHTS,
    gamma: float = DEFAULT_GAMMA,
    c0: float = DEFAULT_C0,
    delta: float = DEFAULT_DELTA,
    c1: float = DEFAULT_C1,
    global_decay: float = DEFAULT_GLOBAL_DECAY,
    latency_override: jnp.ndarray | None = None,
    cost_override: jnp.ndarray | None = None,
    recall_override: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Eq. 1 for a batch of queries: returns utilities ``(N, B)``.

    ``latency_override`` / ``cost_override`` let telemetry-refined estimates
    replace the static priors (paper §IV.A step 2: "using priors and optional
    telemetry"). Shape ``(B,)`` applies one refined vector to every query;
    shape ``(N, B)`` supplies *per-query* priors — the batched serving path
    uses this to evaluate a whole batch in one call even though each query's
    priors reflect the telemetry state at its position in the stream. The
    normalization is per row either way, so an ``(N, B)`` call is exactly N
    stacked ``(B,)`` calls.

    Backend-aware priors: when ``catalog_arrays`` carries ``backend_recall``
    (a backend-aware catalog — bundles.as_arrays), each bundle's quality
    prior is discounted by its retrieval backend's expected recall@k before
    modulation, so Eq. 1 discriminates an approximate/lexical bundle from an
    exact dense one at the same depth. Dense bundles carry recall 1.0 — an
    exact multiplicative identity, so the paper catalog's utilities are
    bit-identical. (Backend *latency* priors arrive already folded into
    ``latency_prior_ms`` / the telemetry store's refined vectors.)

    ``recall_override`` replaces the static ``backend_recall`` column with a
    telemetry-calibrated ``(B,)`` vector
    (``TelemetryStore.refined_recall_priors``) — the closed loop that lets
    measured ``recall_vs_exact`` observations reprice approximate backends.
    Same multiply, same op order, so a ``None`` override (or an override
    equal to the static curve) is bit-identical to the static path.
    """
    lat = (
        jnp.asarray(latency_override, jnp.float32)
        if latency_override is not None
        else catalog_arrays["latency_prior_ms"]
    )
    cost = (
        jnp.asarray(cost_override, jnp.float32)
        if cost_override is not None
        else catalog_arrays["cost_prior_tokens"]
    )
    quality_prior = catalog_arrays["quality_prior"]
    recall = (
        recall_override if recall_override is not None
        else catalog_arrays.get("backend_recall")
    )
    if recall is not None:
        quality_prior = quality_prior * jnp.asarray(recall, jnp.float32)
    qhat = modulated_quality(
        quality_prior,
        catalog_arrays["depth_affinity"],
        complexity,
        gamma=gamma,
        c0=c0,
        delta=delta,
        c1=c1,
        global_decay=global_decay,
    )  # (N, B)
    lat_norm = minmax_normalize(lat)  # (B,) or (N, B); normalized per row
    cost_norm = minmax_normalize(cost)
    if lat_norm.ndim == 1:
        lat_norm = lat_norm[None, :]  # (1, B)
    if cost_norm.ndim == 1:
        cost_norm = cost_norm[None, :]
    w_q, w_l, w_c = weights.as_tuple()
    return w_q * qhat - w_l * lat_norm - w_c * cost_norm


def selection_utilities_np(
    catalog_arrays: Mapping[str, np.ndarray],
    complexity: np.ndarray,
    *,
    weights: UtilityWeights = DEFAULT_WEIGHTS,
    gamma: float = DEFAULT_GAMMA,
    c0: float = DEFAULT_C0,
    delta: float = DEFAULT_DELTA,
    c1: float = DEFAULT_C1,
    global_decay: float = DEFAULT_GLOBAL_DECAY,
    latency_override: np.ndarray | None = None,
    cost_override: np.ndarray | None = None,
    recall_override: np.ndarray | None = None,
) -> np.ndarray:
    """Host (numpy) mirror of :func:`selection_utilities`.

    The serving fast path re-routes position-by-position during its exact
    replay, where a device dispatch per query would dominate; this mirror
    runs in microseconds. It is *bit-identical* to the jnp path: Eq. 1 uses
    only exactly-rounded IEEE-754 float32 ops (multiply/add/divide, min/max,
    clip — no transcendentals), evaluated here in the same order, with every
    Python-float constant cast to float32 first to mirror jax's weak-type
    promotion (numpy would otherwise promote to float64).
    ``tests/test_serving_batched.py`` pins the lockstep — keep both in sync.
    """
    f32 = np.float32
    c = np.asarray(complexity, f32)[..., None]  # (N, 1)
    quality_prior = np.asarray(catalog_arrays["quality_prior"], f32)
    recall = (
        recall_override if recall_override is not None
        else catalog_arrays.get("backend_recall")
    )
    if recall is not None:
        # same op, same order as the jnp path (backend recall discount)
        quality_prior = quality_prior * np.asarray(recall, f32)
    q = quality_prior[None, :]  # (1, B)
    a = np.asarray(catalog_arrays["depth_affinity"], f32)[None, :]
    deep = np.square(np.clip(a, f32(0.0), f32(1.0)))
    hinge = np.maximum(c - f32(c1), f32(0.0))
    decay = f32(global_decay) * np.maximum(c - f32(c0), f32(0.0))
    qhat = (
        np.maximum(q + f32(gamma) * (c - f32(c0)) * a + f32(delta) * hinge * deep, f32(0.0))
        - decay
    )

    lat = np.asarray(
        latency_override if latency_override is not None else catalog_arrays["latency_prior_ms"],
        f32,
    )
    cost = np.asarray(
        cost_override if cost_override is not None else catalog_arrays["cost_prior_tokens"],
        f32,
    )

    def _minmax(values: np.ndarray) -> np.ndarray:
        lo = values.min(axis=-1, keepdims=True)
        hi = values.max(axis=-1, keepdims=True)
        span = hi - lo
        safe = np.where(span > 0, span, f32(1.0))
        return np.where(span > 0, (values - lo) / safe, np.zeros_like(values))

    lat_norm = _minmax(lat)
    cost_norm = _minmax(cost)
    if lat_norm.ndim == 1:
        lat_norm = lat_norm[None, :]
    if cost_norm.ndim == 1:
        cost_norm = cost_norm[None, :]
    w_q, w_l, w_c = (f32(w) for w in weights.as_tuple())
    return w_q * qhat - w_l * lat_norm - w_c * cost_norm


@dataclasses.dataclass(frozen=True)
class RealizedNormalization:
    """Reference budgets used to normalize *observed* latency/cost for Ũ.

    Selection-time priors are model-time estimates in ms; observed end-to-end
    latencies include generation and run into seconds, so realized utility
    normalizes observations against operator reference budgets (an SLO-style
    scale). Observations past the budget push the normalized penalty above 1,
    which is how realized utilities go negative (paper Appendix H sample
    rows, e.g. Ũ = −1.2461 for a 4051 ms direct_llm query).
    """

    latency_ref_ms: float = 2000.0
    cost_ref_tokens: float = 300.0


DEFAULT_REALIZED_NORM = RealizedNormalization()


def realized_utility(
    observed_quality: jnp.ndarray,
    observed_latency_ms: jnp.ndarray,
    observed_cost_tokens: jnp.ndarray,
    *,
    weights: UtilityWeights = DEFAULT_WEIGHTS,
    norm: RealizedNormalization = DEFAULT_REALIZED_NORM,
) -> jnp.ndarray:
    """Post-hoc utility Ũ (paper §V.C): Eq. 1 with observed measurements."""
    w_q, w_l, w_c = weights.as_tuple()
    return (
        w_q * jnp.asarray(observed_quality, jnp.float32)
        - w_l * jnp.asarray(observed_latency_ms, jnp.float32) / norm.latency_ref_ms
        - w_c * jnp.asarray(observed_cost_tokens, jnp.float32) / norm.cost_ref_tokens
    )
