"""Query signals and heuristic complexity (paper §V.A).

Two layers:

* ``extract_signals`` — pure-Python string processing producing numeric
  :class:`QuerySignals` (character length, word count, interrogative cue
  count). Strings cannot be jitted, so this runs on host; it is O(len(q))
  and deterministic.
* ``complexity_from_signals`` / ``batch_complexity`` — pure ``jnp`` and fully
  vectorized, so whole query batches are scored on-device inside the routing
  step.

The paper's formula (§V.A)::

    c(q) = clip(alpha * wordlen(q)/L_max + beta * cues(q)/K_max, 0, 1)

with alpha=0.6, beta=0.4, L_max=20, K_max=3.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# Interrogative / imperative cue words (paper: "interrogative cue-word
# counts"). Includes the imperative analysis verbs that appear in the
# benchmark query set (Appendix D).
CUE_WORDS: frozenset[str] = frozenset(
    {
        "what",
        "why",
        "how",
        "when",
        "where",
        "which",
        "who",
        "whom",
        "whose",
        "explain",
        "describe",
        "compare",
        "contrast",
        "list",
        "define",
        "derive",
    }
)

DEFAULT_ALPHA = 0.6
DEFAULT_BETA = 0.4
DEFAULT_L_MAX = 20.0
DEFAULT_K_MAX = 3.0

_WORD_RE = re.compile(r"[A-Za-z0-9']+")


@dataclasses.dataclass(frozen=True)
class QuerySignals:
    """Numeric per-query signals (paper §IV.A step 1)."""

    char_len: int
    word_count: int
    cue_count: int

    def as_row(self) -> np.ndarray:
        return np.array([self.char_len, self.word_count, self.cue_count], dtype=np.float32)


def extract_signals(query: str) -> QuerySignals:
    """Host-side signal extraction for a single query string."""
    words = _WORD_RE.findall(query.lower())
    cues = sum(1 for w in words if w in CUE_WORDS)
    return QuerySignals(char_len=len(query), word_count=len(words), cue_count=cues)


def extract_signal_matrix(queries: Sequence[str]) -> np.ndarray:
    """Stack signals for a batch of queries into a float32 ``(n, 3)`` matrix.

    Column order: char_len, word_count, cue_count — the layout consumed by
    :func:`batch_complexity`.
    """
    if len(queries) == 0:
        return np.zeros((0, 3), dtype=np.float32)
    return np.stack([extract_signals(q).as_row() for q in queries])


def complexity_from_signals(
    word_count,
    cue_count,
    *,
    alpha: float = DEFAULT_ALPHA,
    beta: float = DEFAULT_BETA,
    l_max: float = DEFAULT_L_MAX,
    k_max: float = DEFAULT_K_MAX,
):
    """Paper Eq. (§V.A): heuristic complexity in [0, 1]. jnp, vectorized."""
    word_count = jnp.asarray(word_count, dtype=jnp.float32)
    cue_count = jnp.asarray(cue_count, dtype=jnp.float32)
    raw = alpha * word_count / l_max + beta * cue_count / k_max
    return jnp.clip(raw, 0.0, 1.0)


def batch_complexity(signal_matrix, **kwargs):
    """Complexity for an ``(n, 3)`` signal matrix (see extract_signal_matrix)."""
    sig = jnp.asarray(signal_matrix, dtype=jnp.float32)
    return complexity_from_signals(sig[:, 1], sig[:, 2], **kwargs)


def complexity(query: str, **kwargs) -> float:
    """Convenience scalar path: string → c(q)."""
    s = extract_signals(query)
    return float(complexity_from_signals(s.word_count, s.cue_count, **kwargs))
