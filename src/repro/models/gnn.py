"""GIN (Graph Isomorphism Network, Xu et al. arXiv:1810.00826) in JAX.

Assigned config ``gin-tu``: 5 layers, hidden 64, sum aggregator, learnable
eps. Message passing is the JAX-native scatter form (kernel_taxonomy §B.3:
"implement via jax.ops.segment_sum over an edge-index → node scatter; this
IS part of the system")::

    agg_i   = Σ_{j : (j→i) ∈ E} h_j            # segment_sum over edges
    h'_i    = MLP_l((1 + ε_l) · h_i + agg_i)

Heads:

* node classification (full_graph_sm / ogb_products cells), and
* graph classification with sum-readout + jumping knowledge over layers
  (molecule cell), per the GIN paper.

``minibatch_lg`` uses a real host-side layered neighbor sampler
(:class:`NeighborSampler`, fanout 15-10) producing static-shape padded
subgraphs (TPU constraint: shapes can't depend on the sample).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    learnable_eps: bool = True
    readout: str = "node"  # node | graph


def init_params(key: jax.Array, cfg: GINConfig) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for l in range(cfg.n_layers):
        d_in = cfg.d_feat if l == 0 else cfg.d_hidden
        layers.append(
            {
                "mlp": mlp_init(keys[l], [d_in, cfg.d_hidden, cfg.d_hidden]),
                "eps": jnp.zeros((), jnp.float32),
            }
        )
    params = {"layers": layers}
    if cfg.readout == "graph":
        # jumping-knowledge: one linear head per layer readout (GIN paper §6)
        params["heads"] = [
            mlp_init(keys[cfg.n_layers], [cfg.d_feat, cfg.n_classes], bias=True)
        ] + [
            mlp_init(jax.random.fold_in(keys[cfg.n_layers + 1], l), [cfg.d_hidden, cfg.n_classes])
            for l in range(cfg.n_layers)
        ]
    else:
        params["head"] = mlp_init(keys[cfg.n_layers], [cfg.d_hidden, cfg.n_classes])
    return params


def gin_conv(layer_params, x, edge_src, edge_dst, n_nodes, edge_mask=None):
    """One GIN layer: scatter-sum aggregation + (1+eps) self + MLP."""
    msgs = x[edge_src]  # gather source features (E, d)
    if edge_mask is not None:
        msgs = msgs * edge_mask[:, None].astype(x.dtype)
    agg = jax.ops.segment_sum(msgs, edge_dst, num_segments=n_nodes)
    h = (1.0 + layer_params["eps"]) * x + agg
    return mlp_apply(layer_params["mlp"], h, activation="relu", final_activation=True)


def node_logits(params, cfg: GINConfig, x, edge_src, edge_dst, *, edge_mask=None):
    """Node-classification forward: (N, d_feat) → (N, n_classes)."""
    n = x.shape[0]
    h = x
    for lp in params["layers"]:
        h = gin_conv(lp, h, edge_src, edge_dst, n, edge_mask)
    return mlp_apply(params["head"], h, activation="relu")


def graph_logits(params, cfg: GINConfig, x, edge_src, edge_dst, graph_ids, n_graphs, *, node_mask=None, edge_mask=None):
    """Graph-classification forward with JK sum-readout per layer."""
    n = x.shape[0]
    h = x
    readouts = []
    hs = [h] + []
    for lp in params["layers"]:
        h = gin_conv(lp, h, edge_src, edge_dst, n, edge_mask)
        hs.append(h)
    logits = 0.0
    for h_l, head in zip(hs, params["heads"]):
        hm = h_l if node_mask is None else h_l * node_mask[:, None].astype(h_l.dtype)
        pooled = jax.ops.segment_sum(hm, graph_ids, num_segments=n_graphs)
        logits = logits + mlp_apply(head, pooled, activation="relu")
    return logits


def node_loss(params, cfg, x, edge_src, edge_dst, labels, label_mask, *, edge_mask=None):
    logits = node_logits(params, cfg, x, edge_src, edge_dst, edge_mask=edge_mask)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    nll = (logz - gold) * label_mask.astype(jnp.float32)
    return jnp.sum(nll) / jnp.maximum(label_mask.sum(), 1.0)


def graph_loss(params, cfg, x, edge_src, edge_dst, graph_ids, n_graphs, labels, **kw):
    logits = graph_logits(params, cfg, x, edge_src, edge_dst, graph_ids, n_graphs, **kw)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# --------------------------------------------------------------------------- #
# Neighbor sampler (host-side, minibatch_lg)                                   #
# --------------------------------------------------------------------------- #
class NeighborSampler:
    """Layered uniform neighbor sampling over a CSR graph (GraphSAGE-style).

    Produces fixed-shape subgraphs: per hop h with fanout f_h every frontier
    node draws exactly f_h neighbors (with replacement; isolated nodes
    self-loop), so a seed batch of B yields B·(1 + f_1 + f_1·f_2 + …) node
    slots and Σ_h B·Πf edges — static shapes for TPU.
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, seed: int = 0):
        self.indptr = np.asarray(indptr, np.int64)
        self.indices = np.asarray(indices, np.int64)
        self.n_nodes = len(indptr) - 1
        self.rng = np.random.default_rng(seed)

    @staticmethod
    def subgraph_shape(batch: int, fanouts: list[int]) -> tuple[int, int]:
        """(n_sub_nodes, n_sub_edges) for given batch/fanouts."""
        nodes, frontier, edges = batch, batch, 0
        for f in fanouts:
            frontier *= f
            nodes += frontier
            edges += frontier
        return nodes, edges

    def sample(self, seeds: np.ndarray, fanouts: list[int]):
        """Returns dict with local-id edge list + node features mapping.

        node_ids: (n_sub,) global ids (slot 0..B-1 = seeds);
        edge_src/edge_dst: (n_edges,) local ids, messages flow src→dst
        (neighbor → frontier node).
        """
        seeds = np.asarray(seeds, np.int64)
        batch = len(seeds)
        node_ids = [seeds]
        frontier = seeds
        frontier_offset = 0  # local id offset of current frontier
        e_src, e_dst = [], []
        next_offset = batch
        for f in fanouts:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            # sample f neighbors per frontier node (with replacement)
            draw = self.rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(frontier), f))
            safe_indices = self.indices if len(self.indices) else np.zeros(1, np.int64)
            gather = np.minimum(
                self.indptr[frontier][:, None] + draw, len(safe_indices) - 1
            )
            nbr = np.where(
                deg[:, None] > 0,
                safe_indices[gather],
                frontier[:, None],  # isolated → self-loop
            )
            nbr_flat = nbr.reshape(-1)
            local_src = next_offset + np.arange(len(nbr_flat))
            local_dst = np.repeat(frontier_offset + np.arange(len(frontier)), f)
            e_src.append(local_src)
            e_dst.append(local_dst)
            node_ids.append(nbr_flat)
            frontier = nbr_flat
            frontier_offset = next_offset
            next_offset += len(nbr_flat)
        return {
            "node_ids": np.concatenate(node_ids),
            "edge_src": np.concatenate(e_src).astype(np.int32),
            "edge_dst": np.concatenate(e_dst).astype(np.int32),
            "n_seeds": batch,
        }


def random_graph(n_nodes: int, n_edges: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Random CSR graph for tests/benchmarks."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, dst.astype(np.int64)
