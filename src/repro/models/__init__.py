"""Model zoo: LM transformers (dense+MoE), GNN, recsys — all pure-pytree JAX."""
