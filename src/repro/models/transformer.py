"""The LM backbone: dense + MoE decoder-only transformer (GQA, RoPE, SwiGLU).

One implementation serves all five assigned LM architectures (internlm2-20b,
phi4-mini, minitron-4b, kimi-k2, granite-moe) via :class:`TransformerConfig`.
Layers are stacked (leading dim L) and executed with ``lax.scan`` so compile
time and HLO size stay O(1) in depth — essential for 48/61-layer dry-runs on
the 512-way host mesh.

Entry points:

* ``forward(params, cfg, tokens)``            → logits (training path)
* ``loss_fn(params, cfg, tokens, targets)``   → scalar LM loss (+aux)
* ``prefill(params, cfg, tokens)``            → last-token logits + KVCache
* ``decode_step(params, cfg, cache, tokens, positions)`` → logits + cache

Sharding is annotation-based: pass a :class:`ShardingPolicy` and the model
drops ``with_sharding_constraint`` on activations / dispatch buffers / cache
writes; pjit propagates the rest from the param/input shardings. With
``policy=None`` the same code runs un-annotated on one device (smoke tests).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.partition import ShardingPolicy
from repro.models import layers as L
from repro.models.kvcache import KVCache
from repro.models.moe import MoEConfig, moe_apply, moe_init, moe_param_count


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    rope_theta: float = 10_000.0
    # MoE (None → dense FFN)
    n_experts: int | None = None
    moe_top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # numerics / memory
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    tie_embeddings: bool = True
    remat: str = "none"  # none | full | dots
    q_block: int | None = None  # chunked prefill attention block
    max_seq_len: int = 4096
    # MoE dispatch grouping: 1 = global capacity (paper-faithful baseline);
    # >1 = per-group (per-data-shard) capacity — see moe.moe_apply_grouped.
    moe_groups: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts is not None and self.n_experts > 0

    def moe_config(self) -> MoEConfig:
        assert self.is_moe
        return MoEConfig(
            n_experts=self.n_experts,
            top_k=self.moe_top_k,
            d_model=self.d_model,
            d_ff=self.d_ff,
            n_shared_experts=self.n_shared_experts,
            capacity_factor=self.capacity_factor,
        )


# --------------------------------------------------------------------------- #
# Params                                                                       #
# --------------------------------------------------------------------------- #
def init_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    dh = cfg.head_dim
    dt = cfg.param_dtype
    k_embed, k_layers, k_head = jax.random.split(key, 3)

    def layer_stack(k):
        ks = jax.random.split(k, 8)
        p = {
            "ln1_scale": jnp.ones((cfg.d_model,), dt),
            "ln2_scale": jnp.ones((cfg.d_model,), dt),
            "wq": L.dense_init(ks[0], cfg.d_model, cfg.n_heads * dh, dt),
            "wk": L.dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * dh, dt),
            "wv": L.dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * dh, dt),
            "wo": L.dense_init(ks[3], cfg.n_heads * dh, cfg.d_model, dt),
        }
        if cfg.is_moe:
            p["moe"] = moe_init(ks[4], cfg.moe_config(), dt)
        else:
            p["w_gate"] = L.dense_init(ks[5], cfg.d_model, cfg.d_ff, dt)
            p["w_up"] = L.dense_init(ks[6], cfg.d_model, cfg.d_ff, dt)
            p["w_down"] = L.dense_init(ks[7], cfg.d_ff, cfg.d_model, dt)
        return p

    # init one layer's params then broadcast-stack with distinct rng per layer
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(layer_stack)(layer_keys)

    params = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, dt),
        "final_scale": jnp.ones((cfg.d_model,), dt),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab, dt)
    return params


def abstract_params(cfg: TransformerConfig) -> dict:
    """ShapeDtypeStruct pytree matching init_params — dry-run stand-in."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def param_count(cfg: TransformerConfig) -> int:
    dh = cfg.head_dim
    n = cfg.vocab * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab
    per_layer = 2 * cfg.d_model  # norms
    per_layer += cfg.d_model * (cfg.n_heads * dh) * 2  # wq, wo
    per_layer += cfg.d_model * (cfg.n_kv_heads * dh) * 2  # wk, wv
    if cfg.is_moe:
        per_layer += moe_param_count(cfg.moe_config())
    else:
        per_layer += 3 * cfg.d_model * cfg.d_ff
    return n + cfg.n_layers * per_layer + cfg.d_model


def active_param_count(cfg: TransformerConfig) -> int:
    """Params touched per token (MoE: top-k experts only) — for 6·N_active·D."""
    if not cfg.is_moe:
        return param_count(cfg)
    from repro.models.moe import moe_active_param_count

    dh = cfg.head_dim
    n = cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab
    per_layer = 2 * cfg.d_model
    per_layer += cfg.d_model * (cfg.n_heads * dh) * 2
    per_layer += cfg.d_model * (cfg.n_kv_heads * dh) * 2
    per_layer += moe_active_param_count(cfg.moe_config())
    return n + cfg.n_layers * per_layer + cfg.d_model


# --------------------------------------------------------------------------- #
# Layer body                                                                   #
# --------------------------------------------------------------------------- #
def _shard(x, spec_fn, policy):
    if policy is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec_fn())


def _attention_block(lp, cfg, x, positions, inv_freq, *, kv_override=None, kv_length=None, q_block=None):
    """Shared attention: returns (attn_out, (k_new, v_new)).

    kv_override: (k, v) each (B, Skv, Hk, dh) — decode path attends to the
    cache instead of the freshly projected kv.
    """
    b, s, _ = x.shape
    dh = cfg.head_dim
    cd = cfg.compute_dtype
    q = (x @ lp["wq"].astype(cd)).reshape(b, s, cfg.n_heads, dh)
    k = (x @ lp["wk"].astype(cd)).reshape(b, s, cfg.n_kv_heads, dh)
    v = (x @ lp["wv"].astype(cd)).reshape(b, s, cfg.n_kv_heads, dh)
    q = L.apply_rope(q, positions, inv_freq)
    k = L.apply_rope(k, positions, inv_freq)
    if kv_override is not None:
        ak, av = kv_override
        out = L.gqa_attention(
            q, ak.astype(cd), av.astype(cd), causal=False, kv_length=kv_length
        )
    else:
        out = L.gqa_attention(q, k, v, causal=True, q_block=q_block)
    out = out.reshape(b, s, cfg.n_heads * dh)
    return out @ lp["wo"].astype(cd), (k, v)


def _ffn_block(lp, cfg, x, policy):
    cd = cfg.compute_dtype
    if cfg.is_moe:
        from jax.sharding import PartitionSpec as _P

        moe_params = {k: v.astype(cd) if k != "router" else v for k, v in lp["moe"].items()}
        if cfg.moe_groups > 1:
            constraint = token_constraint = None
            if policy is not None:
                buf_spec = _P(policy.dp, policy.tp, None, None)  # (G, E, C, d)
                tok_spec = _P(policy.dp, None, None)  # (G, Tg·k, d)
                constraint = lambda b: jax.lax.with_sharding_constraint(b, buf_spec)
                token_constraint = lambda p: jax.lax.with_sharding_constraint(p, tok_spec)
            from repro.models.moe import moe_apply_grouped

            return moe_apply_grouped(
                moe_params,
                cfg.moe_config(),
                x,
                cfg.moe_groups,
                dispatch_constraint=constraint,
                token_constraint=token_constraint,
            )
        constraint = token_constraint = None
        if policy is not None:
            spec = policy.moe_dispatch()
            tok_spec = _P(policy.dp, None)  # flat (T·k, d) pair tensors
            constraint = lambda b: jax.lax.with_sharding_constraint(b, spec)
            token_constraint = lambda p: jax.lax.with_sharding_constraint(p, tok_spec)
        y, aux = moe_apply(
            moe_params,
            cfg.moe_config(),
            x,
            dispatch_constraint=constraint,
            token_constraint=token_constraint,
        )
        return y, aux
    y = L.swiglu(x @ lp["w_gate"].astype(cd), x @ lp["w_up"].astype(cd)) @ lp["w_down"].astype(cd)
    return y, {"aux_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}


def _make_layer_fn(cfg, policy, positions, inv_freq, *, mode, q_block=None, kv_length=None):
    """Build the scan body for ``mode`` ∈ {train, prefill}."""

    def body(carry, lp):
        x, aux_acc = carry
        h = L.rmsnorm({"scale": lp["ln1_scale"]}, x)
        attn, (k_new, v_new) = _attention_block(
            lp, cfg, h, positions, inv_freq, q_block=q_block
        )
        x = _shard(x + attn, policy.activations if policy else None, policy)
        h2 = L.rmsnorm({"scale": lp["ln2_scale"]}, x)
        ffn, aux = _ffn_block(lp, cfg, h2, policy)
        x = _shard(x + ffn, policy.activations if policy else None, policy)
        aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
        if mode == "prefill":
            return (x, aux_acc), (k_new, v_new)
        return (x, aux_acc), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return body


def _trunk(params, cfg: TransformerConfig, tokens, positions, *, policy, mode, q_block=None):
    cd = cfg.compute_dtype
    x = params["embed"].astype(cd)[tokens]  # gather (B, S, d)
    x = _shard(x, policy.activations if policy else None, policy)
    inv_freq = L.rope_frequencies(cfg.head_dim, cfg.rope_theta)
    body = _make_layer_fn(cfg, policy, positions, inv_freq, mode=mode, q_block=q_block)
    aux0 = {"aux_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    (x, aux), kv = jax.lax.scan(body, (x, aux0), params["layers"])
    x = L.rmsnorm({"scale": params["final_scale"]}, x)
    return x, aux, kv


def _logits(params, cfg, x, policy):
    cd = cfg.compute_dtype
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(cd)
    return _shard(logits, policy.logits if policy else None, policy)


# --------------------------------------------------------------------------- #
# Public entry points                                                          #
# --------------------------------------------------------------------------- #
def forward(params, cfg: TransformerConfig, tokens: jnp.ndarray, *, policy: ShardingPolicy | None = None):
    """Training-path forward: tokens (B, S) → logits (B, S, V) + aux."""
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x, aux, _ = _trunk(params, cfg, tokens, positions, policy=policy, mode="train", q_block=cfg.q_block)
    return _logits(params, cfg, x, policy), aux


def loss_fn(
    params,
    cfg: TransformerConfig,
    tokens: jnp.ndarray,
    targets: jnp.ndarray,
    *,
    mask: jnp.ndarray | None = None,
    policy: ShardingPolicy | None = None,
    aux_weight: float = 0.01,
    z_weight: float = 1e-4,
    loss_chunk: int | None = None,
):
    """Next-token cross-entropy (f32 logsumexp) + MoE aux losses.

    ``loss_chunk`` splits the sequence for the unembed+CE so the (B, S, V)
    f32 logits tensor never materializes — per chunk it is (B, chunk, V),
    recomputed in the backward (checkpointed). Big-vocab models at long S
    need this to fit HBM (e.g. 256×4096×92544 f32 = 389 GB global).
    """
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x, aux, _ = _trunk(params, cfg, tokens, positions, policy=policy, mode="train", q_block=cfg.q_block)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = mask.astype(jnp.float32)

    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    head = head.astype(cfg.compute_dtype)

    def chunk_nll(x_c, t_c, m_c):
        logits = (x_c @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * m_c)

    if loss_chunk is None or loss_chunk >= s:
        nll_sum = chunk_nll(x, targets, mask)
    else:
        if s % loss_chunk:
            raise ValueError(f"seq {s} not divisible by loss_chunk {loss_chunk}")
        ck = jax.checkpoint(chunk_nll)
        nll_sum = 0.0
        for i in range(s // loss_chunk):
            sl = slice(i * loss_chunk, (i + 1) * loss_chunk)
            nll_sum = nll_sum + ck(x[:, sl], targets[:, sl], mask[:, sl])
    loss = nll_sum / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux_weight * aux["aux_loss"] + z_weight * aux["z_loss"]
    return total, {"lm_loss": loss, **aux}


def prefill(params, cfg: TransformerConfig, tokens: jnp.ndarray, *, max_len: int | None = None, policy: ShardingPolicy | None = None):
    """Prompt processing: returns (last-token logits (B, V), KVCache).

    Only the final position's logits are computed — prefill never
    materializes the (B, S, V) logits tensor.
    """
    b, s = tokens.shape
    max_len = max_len if max_len is not None else cfg.max_seq_len
    if max_len < s:
        raise ValueError(f"max_len {max_len} < prompt {s}")
    positions = jnp.arange(s, dtype=jnp.int32)
    x, _, kv = _trunk(params, cfg, tokens, positions, policy=policy, mode="prefill", q_block=cfg.q_block)
    k_stack, v_stack = kv  # (L, B, S, Hk, dh)
    pad = max_len - s
    if pad:
        padding = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
        k_stack = jnp.pad(k_stack, padding)
        v_stack = jnp.pad(v_stack, padding)
    cache = KVCache(
        k=k_stack.astype(cfg.compute_dtype),
        v=v_stack.astype(cfg.compute_dtype),
        lengths=jnp.full((b,), s, jnp.int32),
    )
    if policy is not None:
        cache = dataclasses.replace(
            cache,
            k=jax.lax.with_sharding_constraint(cache.k, policy.kv_cache()),
            v=jax.lax.with_sharding_constraint(cache.v, policy.kv_cache()),
        )
    last = x[:, -1, :]
    logits = _logits(params, cfg, last[:, None, :], policy)[:, 0, :]
    return logits, cache


def decode_step(
    params,
    cfg: TransformerConfig,
    cache: KVCache,
    tokens: jnp.ndarray,  # (B,) int32 — the freshly sampled token per seq
    *,
    policy: ShardingPolicy | None = None,
):
    """One serve_step: append token, attend to cache, emit next logits.

    Per-sequence positions come from ``cache.lengths`` (continuous batching:
    sequences at different depths share the batch).
    """
    cd = cfg.compute_dtype
    b = tokens.shape[0]
    positions = cache.lengths  # (B,)
    x = params["embed"].astype(cd)[tokens][:, None, :]  # (B, 1, d)
    inv_freq = L.rope_frequencies(cfg.head_dim, cfg.rope_theta)
    dh = cfg.head_dim

    # Decode scans over (layer params, per-layer cache slices); each step
    # writes the new token into its slice and attends against it, so the
    # cache stack is threaded through scan ys rather than the carry.
    def layer_step(x, inputs):
        lp, k_cache, v_cache = inputs  # k_cache: (B, S_max, Hk, dh)
        h = L.rmsnorm({"scale": lp["ln1_scale"]}, x)
        q = (h @ lp["wq"].astype(cd)).reshape(b, 1, cfg.n_heads, dh)
        k1 = (h @ lp["wk"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
        v1 = (h @ lp["wv"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
        q = L.apply_rope(q, positions[:, None], inv_freq)
        k1 = L.apply_rope(k1, positions[:, None], inv_freq)
        batch_idx = jnp.arange(b)
        k_cache = k_cache.at[batch_idx, positions].set(k1[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[batch_idx, positions].set(v1[:, 0].astype(v_cache.dtype))
        attn = L.gqa_attention(
            q,
            k_cache.astype(cd),
            v_cache.astype(cd),
            causal=False,
            kv_length=positions + 1,
        ).reshape(b, 1, cfg.n_heads * dh)
        x = x + attn @ lp["wo"].astype(cd)
        h2 = L.rmsnorm({"scale": lp["ln2_scale"]}, x)
        ffn, _ = _ffn_block(lp, cfg, h2, policy)
        return x + ffn, (k_cache, v_cache)

    def scan_body(x, inputs):
        x, (k_new, v_new) = layer_step(x, inputs)
        return x, (k_new, v_new)

    x, (k_all, v_all) = jax.lax.scan(scan_body, x, (params["layers"], cache.k, cache.v))
    new_cache = KVCache(k=k_all, v=v_all, lengths=cache.lengths + 1)
    if policy is not None:
        new_cache = dataclasses.replace(
            new_cache,
            k=jax.lax.with_sharding_constraint(new_cache.k, policy.kv_cache()),
            v=jax.lax.with_sharding_constraint(new_cache.v, policy.kv_cache()),
        )
    x = L.rmsnorm({"scale": params["final_scale"]}, x)
    logits = _logits(params, cfg, x, policy)[:, 0, :]
    return logits, new_cache


def decode_step_q8(
    params,
    cfg: TransformerConfig,
    k_q: jnp.ndarray,  # (L, B, S, Hk, dh) int8
    k_scale: jnp.ndarray,  # (L, B, S, Hk) f32
    v_q: jnp.ndarray,
    v_scale: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,)
    tokens: jnp.ndarray,  # (B,)
    *,
    policy: ShardingPolicy | None = None,
):
    """decode_step over an int8-quantized KV cache (KIVI-style).

    Each layer dequantizes only ITS cache slice inside the scan (per-token
    per-head absmax scales), appends the new token quantized, and attends.
    Returns (logits, new k_q, new k_scale, new v_q, new v_scale, lengths).
    The Pallas twin (kernels/decode_attention/decode_attention_q8_pallas)
    fuses the dequant into the attention kernel on TPU.
    """
    from repro.kernels.decode_attention.kernel import quantize_kv

    cd = cfg.compute_dtype
    b = tokens.shape[0]
    positions = lengths
    x = params["embed"].astype(cd)[tokens][:, None, :]
    inv_freq = L.rope_frequencies(cfg.head_dim, cfg.rope_theta)
    dh = cfg.head_dim

    def layer_step(x, inputs):
        lp, kq_l, ks_l, vq_l, vs_l = inputs
        h = L.rmsnorm({"scale": lp["ln1_scale"]}, x)
        q = (h @ lp["wq"].astype(cd)).reshape(b, 1, cfg.n_heads, dh)
        k1 = (h @ lp["wk"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
        v1 = (h @ lp["wv"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
        q = L.apply_rope(q, positions[:, None], inv_freq)
        k1 = L.apply_rope(k1, positions[:, None], inv_freq)
        # quantize + append the new token
        k1q, k1s = quantize_kv(k1)
        v1q, v1s = quantize_kv(v1)
        bi = jnp.arange(b)
        kq_l = kq_l.at[bi, positions].set(k1q[:, 0])
        ks_l = ks_l.at[bi, positions].set(k1s[:, 0])
        vq_l = vq_l.at[bi, positions].set(v1q[:, 0])
        vs_l = vs_l.at[bi, positions].set(v1s[:, 0])
        # dequantize this layer's slice for attention
        k_deq = (kq_l.astype(cd) * ks_l[..., None].astype(cd))
        v_deq = (vq_l.astype(cd) * vs_l[..., None].astype(cd))
        attn = L.gqa_attention(
            q, k_deq, v_deq, causal=False, kv_length=positions + 1
        ).reshape(b, 1, cfg.n_heads * dh)
        x = x + attn @ lp["wo"].astype(cd)
        h2 = L.rmsnorm({"scale": lp["ln2_scale"]}, x)
        ffn, _ = _ffn_block(lp, cfg, h2, policy)
        return x + ffn, (kq_l, ks_l, vq_l, vs_l)

    x, (kq, ks, vq, vs) = jax.lax.scan(
        lambda x, inp: layer_step(x, inp), x, (params["layers"], k_q, k_scale, v_q, v_scale)
    )
    x = L.rmsnorm({"scale": params["final_scale"]}, x)
    logits = _logits(params, cfg, x, policy)[:, 0, :]
    return logits, kq, ks, vq, vs, lengths + 1


def greedy_generate(params, cfg, prompt_tokens, n_new: int, *, max_len=None, policy=None):
    """Greedy decode loop (host-driven): prefill + n_new decode steps."""
    max_len = max_len or (prompt_tokens.shape[1] + n_new)
    logits, cache = prefill(params, cfg, prompt_tokens, max_len=max_len, policy=policy)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(n_new):
        out.append(tok)
        logits, cache = decode_step(params, cfg, cache, tok, policy=policy)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)  # (B, n_new)
