"""RecSys model family: DLRM, DeepFM, MIND, SASRec + manual EmbeddingBag.

JAX has no native EmbeddingBag or CSR sparse — per kernel_taxonomy §B.6 the
lookup is implemented as ``jnp.take`` + ``jax.ops.segment_sum`` (and the
Pallas ``embedding_bag`` kernel is its TPU hot-path twin). All four models
share one combined-table convention: per-field vocabs are concatenated into
a single ``(Σ vocab_f, dim)`` table with per-field row offsets, so a batch
of categorical ids does ONE gather — the layout FBGEMM's TBE uses, and what
lets the table shard row-wise over the mesh.

Shapes contract (assigned cells): ``train_step(params, batch)`` for
train_batch; ``serve_step(params, batch) → scores`` for serve_p99 /
serve_bulk; ``retrieval_score(query, candidates) → top-k`` for
retrieval_cand (1 query × 10⁶ candidates — batched dot + blocked top-k,
never a loop).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import mlp_apply, mlp_init
from repro.retrieval.topk import blocked_topk


# --------------------------------------------------------------------------- #
# EmbeddingBag (manual: gather + segment-reduce)                                #
# --------------------------------------------------------------------------- #
def embedding_bag(
    table: jnp.ndarray,  # (V, D)
    indices: jnp.ndarray,  # (n_lookups,) int32 flat ids
    segment_ids: jnp.ndarray,  # (n_lookups,) int32 → output bag
    n_bags: int,
    *,
    mode: str = "sum",
    weights: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """torch.nn.EmbeddingBag equivalent: (n_bags, D)."""
    rows = table[indices]
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(jnp.ones_like(segment_ids, rows.dtype), segment_ids, n_bags)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=n_bags)
    raise ValueError(f"unknown mode {mode!r}")


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """Combined-table layout for n_fields categorical features."""

    vocab_sizes: tuple[int, ...]

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(np.int64)


def field_lookup(table: jnp.ndarray, spec: FieldSpec, ids: jnp.ndarray) -> jnp.ndarray:
    """Single-hot per-field lookup: ids (B, F) → (B, F, D), one gather."""
    offs = jnp.asarray(spec.offsets, ids.dtype)
    return table[ids + offs[None, :]]


# --------------------------------------------------------------------------- #
# DLRM (MLPerf config; arXiv:1906.00091)                                       #
# --------------------------------------------------------------------------- #
# Criteo-1TB per-field vocabulary sizes (MLPerf DLRM reference).
CRITEO_VOCAB_SIZES: tuple[int, ...] = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    embed_dim: int = 128
    bot_mlp: tuple[int, ...] = (512, 256, 128)
    top_mlp: tuple[int, ...] = (1024, 1024, 512, 256, 1)
    vocab_sizes: tuple[int, ...] = CRITEO_VOCAB_SIZES
    param_dtype: object = jnp.float32

    @property
    def fields(self) -> FieldSpec:
        return FieldSpec(self.vocab_sizes)

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def interaction_dim(self) -> int:
        f = self.n_sparse + 1  # embeddings + bottom-MLP output
        return f * (f - 1) // 2 + self.bot_mlp[-1]


def dlrm_init(key, cfg: DLRMConfig) -> dict:
    k_emb, k_bot, k_top = jax.random.split(key, 3)
    scale = 1.0 / np.sqrt(cfg.embed_dim)
    return {
        "table": (jax.random.uniform(k_emb, (cfg.fields.total_rows, cfg.embed_dim), minval=-scale, maxval=scale)).astype(cfg.param_dtype),
        "bot": mlp_init(k_bot, [cfg.n_dense, *cfg.bot_mlp]),
        "top": mlp_init(k_top, [cfg.interaction_dim, *cfg.top_mlp]),
    }


def dlrm_abstract(cfg: DLRMConfig) -> dict:
    """ShapeDtypeStruct params (the 96 GB table is never allocated host-side)."""
    return jax.eval_shape(lambda k: dlrm_init(k, cfg), jax.random.PRNGKey(0))


def dlrm_forward(params, cfg: DLRMConfig, dense: jnp.ndarray, sparse_ids: jnp.ndarray) -> jnp.ndarray:
    """dense (B, 13) f32, sparse_ids (B, 26) int32 (field-local) → logits (B,)."""
    b = dense.shape[0]
    bot = mlp_apply(params["bot"], dense, activation="relu", final_activation=True)  # (B, 128)
    emb = field_lookup(params["table"], cfg.fields, sparse_ids)  # (B, 26, 128)
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, 27, 128)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)  # dot interaction
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    pairs = inter[:, iu, ju]  # (B, f(f-1)/2)
    z = jnp.concatenate([bot, pairs], axis=-1)
    return mlp_apply(params["top"], z, activation="relu")[:, 0]


def dlrm_loss(params, cfg, dense, sparse_ids, labels):
    logits = dlrm_forward(params, cfg, dense, sparse_ids)
    return _bce(logits, labels)


def _bce(logits, labels):
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# --------------------------------------------------------------------------- #
# DeepFM (arXiv:1703.04247)                                                     #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    mlp: tuple[int, ...] = (400, 400, 400)
    vocab_per_field: int = 100_000
    param_dtype: object = jnp.float32

    @property
    def fields(self) -> FieldSpec:
        return FieldSpec((self.vocab_per_field,) * self.n_sparse)


def deepfm_init(key, cfg: DeepFMConfig) -> dict:
    k_emb, k_w, k_mlp = jax.random.split(key, 3)
    rows = cfg.fields.total_rows
    return {
        "table": (jax.random.normal(k_emb, (rows, cfg.embed_dim)) * 0.01).astype(cfg.param_dtype),
        "first_order": (jax.random.normal(k_w, (rows, 1)) * 0.01).astype(cfg.param_dtype),
        "bias": jnp.zeros((), jnp.float32),
        "mlp": mlp_init(k_mlp, [cfg.n_sparse * cfg.embed_dim, *cfg.mlp, 1]),
    }


def deepfm_forward(params, cfg: DeepFMConfig, sparse_ids: jnp.ndarray) -> jnp.ndarray:
    """sparse_ids (B, 39) field-local → logits (B,)."""
    b = sparse_ids.shape[0]
    emb = field_lookup(params["table"], cfg.fields, sparse_ids)  # (B, F, D)
    offs = jnp.asarray(cfg.fields.offsets, sparse_ids.dtype)
    fo = params["first_order"][sparse_ids + offs[None, :]][..., 0].sum(-1)  # (B,)
    # FM 2nd order: ½((Σv)² − Σv²) summed over dim
    sum_v = emb.sum(axis=1)
    fm = 0.5 * (jnp.square(sum_v) - jnp.square(emb).sum(axis=1)).sum(-1)
    deep = mlp_apply(params["mlp"], emb.reshape(b, -1), activation="relu")[:, 0]
    return params["bias"] + fo + fm + deep


def deepfm_loss(params, cfg, sparse_ids, labels):
    return _bce(deepfm_forward(params, cfg, sparse_ids), labels)


# --------------------------------------------------------------------------- #
# MIND (multi-interest capsules; arXiv:1904.08030)                              #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 400_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    n_negatives: int = 1024
    power: float = 2.0  # label-aware attention sharpness
    param_dtype: object = jnp.float32


def mind_init(key, cfg: MINDConfig) -> dict:
    k_emb, k_s = jax.random.split(key)
    return {
        "item_embed": (jax.random.normal(k_emb, (cfg.n_items, cfg.embed_dim)) * 0.02).astype(cfg.param_dtype),
        "s_matrix": (jax.random.normal(k_s, (cfg.embed_dim, cfg.embed_dim)) * (1 / np.sqrt(cfg.embed_dim))).astype(cfg.param_dtype),
    }


def _squash(x, axis=-1, eps=1e-9):
    n2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + eps)


def mind_interests(params, cfg: MINDConfig, hist_ids: jnp.ndarray, hist_mask: jnp.ndarray) -> jnp.ndarray:
    """B2I dynamic routing: history (B, L) → interest capsules (B, K, D)."""
    e = params["item_embed"][hist_ids]  # (B, L, D)
    eh = e @ params["s_matrix"]  # bilinear map (shared, per MIND B2I)
    b_logits = jnp.zeros((e.shape[0], cfg.n_interests, e.shape[1]), jnp.float32)
    mask = hist_mask[:, None, :].astype(jnp.float32)  # (B, 1, L)

    def routing_iter(b_logits, _):
        w = jax.nn.softmax(b_logits, axis=1) * mask  # compete over capsules
        z = jnp.einsum("bkl,bld->bkd", w, eh)
        caps = _squash(z)
        b_new = b_logits + jnp.einsum("bkd,bld->bkl", caps, eh)
        return b_new, caps

    b_final, caps_seq = jax.lax.scan(routing_iter, b_logits, None, length=cfg.capsule_iters)
    return caps_seq[-1]  # (B, K, D)


def mind_loss(params, cfg: MINDConfig, hist_ids, hist_mask, target_ids, neg_ids):
    """Sampled-softmax with label-aware attention over interests."""
    caps = mind_interests(params, cfg, hist_ids, hist_mask)  # (B, K, D)
    tgt = params["item_embed"][target_ids]  # (B, D)
    att = jax.nn.softmax(
        cfg.power * jnp.einsum("bkd,bd->bk", caps, tgt).astype(jnp.float32), axis=-1
    )
    user = jnp.einsum("bk,bkd->bd", att.astype(caps.dtype), caps)  # (B, D)
    pos = jnp.einsum("bd,bd->b", user, tgt).astype(jnp.float32)
    negs = params["item_embed"][neg_ids]  # (N, D) shared negatives
    neg = (user @ negs.T).astype(jnp.float32)  # (B, N)
    logits = jnp.concatenate([pos[:, None], neg], axis=-1)
    return jnp.mean(jax.nn.logsumexp(logits, -1) - pos)


def mind_retrieval_score(params, cfg: MINDConfig, hist_ids, hist_mask, candidate_emb, k: int):
    """Serve path: max-over-interests dot against candidates + top-k."""
    caps = mind_interests(params, cfg, hist_ids, hist_mask)  # (B, K, D)
    scores = jnp.einsum("bkd,nd->bkn", caps, candidate_emb).max(axis=1)  # (B, N)
    return blocked_topk(scores, k)


# --------------------------------------------------------------------------- #
# SASRec (arXiv:1808.09781)                                                     #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 54_542  # Amazon Beauty
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    param_dtype: object = jnp.float32


def sasrec_init(key, cfg: SASRecConfig) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + i], 5)
        d = cfg.embed_dim
        blocks.append(
            {
                "wq": (jax.random.normal(kb[0], (d, d)) / np.sqrt(d)).astype(cfg.param_dtype),
                "wk": (jax.random.normal(kb[1], (d, d)) / np.sqrt(d)).astype(cfg.param_dtype),
                "wv": (jax.random.normal(kb[2], (d, d)) / np.sqrt(d)).astype(cfg.param_dtype),
                "ln1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
                "ln2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
                "ffn": mlp_init(kb[3], [d, d, d]),
            }
        )
    return {
        # row 0 is the padding item
        "item_embed": (jax.random.normal(ks[0], (cfg.n_items + 1, cfg.embed_dim)) * 0.02).astype(cfg.param_dtype),
        "pos_embed": (jax.random.normal(ks[1], (cfg.seq_len, cfg.embed_dim)) * 0.02).astype(cfg.param_dtype),
        "blocks": blocks,
    }


def sasrec_hidden(params, cfg: SASRecConfig, seq_ids: jnp.ndarray) -> jnp.ndarray:
    """seq_ids (B, L) (0 = pad) → hidden states (B, L, D), causal."""
    from repro.models.layers import layernorm

    b, l = seq_ids.shape
    x = params["item_embed"][seq_ids] + params["pos_embed"][None, :l]
    pad_mask = (seq_ids > 0)[:, None, None, :]  # (B,1,1,L) keys
    causal = jnp.tril(jnp.ones((l, l), bool))[None, None]
    mask = causal & pad_mask
    d = cfg.embed_dim
    scale = 1.0 / np.sqrt(d)
    for blk in params["blocks"]:
        h = layernorm(blk["ln1"], x)
        q, k, v = h @ blk["wq"], h @ blk["wk"], h @ blk["wv"]
        # single-head (paper config) attention
        scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32)[:, None] * scale
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)[:, 0]
        probs = jnp.where(jnp.isnan(probs), 0.0, probs)
        x = x + jnp.einsum("bqk,bkd->bqd", probs.astype(v.dtype), v)
        h2 = layernorm(blk["ln2"], x)
        x = x + mlp_apply(blk["ffn"], h2, activation="relu")
    # zero out pad positions
    return x * (seq_ids > 0)[..., None].astype(x.dtype)


def sasrec_loss(params, cfg: SASRecConfig, seq_ids, pos_ids, neg_ids):
    """Paper objective: BCE(pos) + BCE(neg) at every valid position."""
    h = sasrec_hidden(params, cfg, seq_ids)  # (B, L, D)
    pos_e = params["item_embed"][pos_ids]
    neg_e = params["item_embed"][neg_ids]
    pos_logit = jnp.einsum("bld,bld->bl", h, pos_e).astype(jnp.float32)
    neg_logit = jnp.einsum("bld,bld->bl", h, neg_e).astype(jnp.float32)
    valid = (pos_ids > 0).astype(jnp.float32)
    loss = -(jax.nn.log_sigmoid(pos_logit) + jax.nn.log_sigmoid(-neg_logit)) * valid
    return jnp.sum(loss) / jnp.maximum(valid.sum(), 1.0)


def sasrec_retrieval_score(params, cfg: SASRecConfig, seq_ids, candidate_emb, k: int):
    """Last-position user state vs candidate items → top-k."""
    h = sasrec_hidden(params, cfg, seq_ids)
    # last valid position per sequence
    lengths = (seq_ids > 0).sum(-1)
    last = h[jnp.arange(h.shape[0]), jnp.maximum(lengths - 1, 0)]  # (B, D)
    scores = last @ candidate_emb.T
    return blocked_topk(scores, k)
