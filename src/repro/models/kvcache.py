"""KV caches: contiguous (dry-run/serving default) and paged (vLLM-style).

Contiguous layout: k, v ``(L, B, S_max, Hk, dh)`` + per-sequence lengths
``(B,)``. Under the SP policy the S_max axis shards over ``model`` —
each model shard owns a sequence slice and decode attention reduces over it
(distributed flash-decoding; see distributed/partition.py).

Paged layout: a global page pool ``(n_pages, page_size, Hk, dh)`` per k/v
per layer plus a block table ``(B, max_pages)`` — the PagedAttention
indirection adapted to JAX static shapes (block tables are dense int32 with
-1 padding). Serving's scheduler allocates/frees pages on the host;
gather-by-table happens on device.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class KVCache:
    """Contiguous cache pytree (registered manually via tree_util)."""

    k: jnp.ndarray  # (L, B, S_max, Hk, dh)
    v: jnp.ndarray
    lengths: jnp.ndarray  # (B,) int32 valid prefix per sequence

    @property
    def max_len(self) -> int:
        return self.k.shape[2]

    @classmethod
    def zeros(cls, n_layers, batch, max_len, n_kv_heads, d_head, dtype=jnp.bfloat16):
        shape = (n_layers, batch, max_len, n_kv_heads, d_head)
        return cls(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            lengths=jnp.zeros((batch,), jnp.int32),
        )

    @classmethod
    def spec(cls, n_layers, batch, max_len, n_kv_heads, d_head, dtype=jnp.bfloat16):
        """ShapeDtypeStruct stand-in for dry-runs (no allocation)."""
        shape = (n_layers, batch, max_len, n_kv_heads, d_head)
        return cls(
            k=jax.ShapeDtypeStruct(shape, dtype),
            v=jax.ShapeDtypeStruct(shape, dtype),
            lengths=jax.ShapeDtypeStruct((batch,), jnp.int32),
        )

    def write_token(self, layer: int, k_new: jnp.ndarray, v_new: jnp.ndarray, positions: jnp.ndarray):
        """Write one token per sequence at per-sequence ``positions`` (B,).

        k_new/v_new: (B, Hk, dh). Returns updated cache arrays for ``layer``.
        """
        b = positions.shape[0]
        batch_idx = jnp.arange(b)
        k = self.k.at[layer, batch_idx, positions].set(k_new.astype(self.k.dtype))
        v = self.v.at[layer, batch_idx, positions].set(v_new.astype(self.v.dtype))
        return dataclasses.replace(self, k=k, v=v)

    def advanced(self, n: int = 1) -> "KVCache":
        return dataclasses.replace(self, lengths=self.lengths + n)


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "lengths"], meta_fields=[]
)


# --------------------------------------------------------------------------- #
# Paged cache                                                                  #
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class PagedKVCache:
    """Page-pool cache with dense block tables (PagedAttention, TPU-adapted)."""

    k_pages: jnp.ndarray  # (L, n_pages, page, Hk, dh)
    v_pages: jnp.ndarray
    block_table: jnp.ndarray  # (B, max_pages) int32; -1 = unallocated
    lengths: jnp.ndarray  # (B,)
    page_size: int

    @classmethod
    def zeros(cls, n_layers, n_pages, page_size, batch, max_pages, n_kv_heads, d_head, dtype=jnp.bfloat16):
        return cls(
            k_pages=jnp.zeros((n_layers, n_pages, page_size, n_kv_heads, d_head), dtype),
            v_pages=jnp.zeros((n_layers, n_pages, page_size, n_kv_heads, d_head), dtype),
            block_table=jnp.full((batch, max_pages), -1, jnp.int32),
            lengths=jnp.zeros((batch,), jnp.int32),
            page_size=page_size,
        )

    def gather_kv(self, layer: int, max_len: int) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Materialize (B, max_len, Hk, dh) views via the block table.

        max_len must be a multiple of page_size. Returns (k, v, valid_mask).
        """
        if max_len % self.page_size:
            raise ValueError("max_len must be a multiple of page_size")
        n = max_len // self.page_size
        table = self.block_table[:, :n]  # (B, n)
        safe = jnp.maximum(table, 0)
        k = self.k_pages[layer][safe]  # (B, n, page, Hk, dh)
        v = self.v_pages[layer][safe]
        b = table.shape[0]
        k = k.reshape(b, max_len, *k.shape[3:])
        v = v.reshape(b, max_len, *v.shape[3:])
        pos = jnp.arange(max_len)[None, :]
        page_ok = jnp.repeat(table >= 0, self.page_size, axis=1)
        valid = (pos < self.lengths[:, None]) & page_ok
        return k, v, valid


jax.tree_util.register_dataclass(
    PagedKVCache,
    data_fields=["k_pages", "v_pages", "block_table", "lengths"],
    meta_fields=["page_size"],
)


class PageAllocator:
    """Host-side page pool bookkeeping for the serving scheduler."""

    def __init__(self, n_pages: int):
        self.free = list(range(n_pages - 1, -1, -1))
        self.owned: dict[int, list[int]] = {}

    def alloc(self, seq_id: int, n: int) -> list[int]:
        if len(self.free) < n:
            raise MemoryError(f"page pool exhausted (need {n}, have {len(self.free)})")
        pages = [self.free.pop() for _ in range(n)]
        self.owned.setdefault(seq_id, []).extend(pages)
        return pages

    def free_seq(self, seq_id: int) -> int:
        pages = self.owned.pop(seq_id, [])
        self.free.extend(pages)
        return len(pages)

    @property
    def n_free(self) -> int:
        return len(self.free)
