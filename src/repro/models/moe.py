"""Mixture-of-Experts FFN with capacity-based sparse dispatch (EP-ready).

Top-k routing (GShard/Switch lineage) with *static shapes* throughout — the
TPU constraint. Instead of a dense (tokens × experts) einsum (which would
inflate FLOPs by E/k — 48× for kimi's 384-expert top-8), tokens are
physically dispatched to per-expert capacity buffers:

    router probs (T, E) → top-k (ids, gates)
    sort token-expert pairs by expert → position-in-expert
    keep = position < capacity                  (overflow tokens drop)
    scatter x → dispatch buffer (E, C, d)       [all-to-all under EP]
    per-expert FFN: (E, C, d) @ (E, d, f) → … → (E, C, d)
    gather back + gate-weighted combine

so compiled FLOPs track *active* expert compute (≈ T·k·cf · expert FLOPs) —
the quantity the roofline's MODEL_FLOPS/HLO_FLOPs ratio checks.

Load-balancing aux loss (Switch: E · Σ_e f_e · p̄_e) and router z-loss are
returned for the training objective.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int  # per-expert hidden
    n_shared_experts: int = 0  # DeepSeek/Kimi-style always-on experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"

    @property
    def capacity(self) -> int:
        # per-expert slots for T tokens is computed at call time; this is the
        # per-token multiplier
        return 0


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    params = {
        "router": dense_init(ks[0], cfg.d_model, cfg.n_experts, jnp.float32),
        "e_gate": _expert_init(ks[1], cfg.n_experts, cfg.d_model, cfg.d_ff, dtype),
        "e_up": _expert_init(ks[2], cfg.n_experts, cfg.d_model, cfg.d_ff, dtype),
        "e_down": _expert_init(ks[3], cfg.n_experts, cfg.d_ff, cfg.d_model, dtype),
    }
    if cfg.n_shared_experts:
        sf = cfg.d_ff * cfg.n_shared_experts
        params["s_gate"] = dense_init(ks[4], cfg.d_model, sf, dtype)
        params["s_up"] = dense_init(ks[5], cfg.d_model, sf, dtype)
        params["s_down"] = dense_init(ks[6], sf, cfg.d_model, dtype)
    return params


def _expert_init(key, e, d_in, d_out, dtype):
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out)) * scale).astype(dtype)


def router_topk(
    logits: jnp.ndarray, top_k: int, *, normalize_gates: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray, dict]:
    """(T, E) logits → (T, k) expert ids + gates + aux losses."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (T, k)
    if normalize_gates:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e (token_fraction_e * mean_prob_e)
    t, e = probs.shape
    onehot_top1 = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    frac = onehot_top1.mean(0)
    mean_prob = probs.mean(0)
    aux = e * jnp.sum(frac * mean_prob)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)))
    return expert_ids, gate_vals, {"aux_loss": aux, "z_loss": zloss}


def dispatch_indices(
    expert_ids: jnp.ndarray, n_experts: int, capacity: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based position-in-expert computation.

    expert_ids: (T, k) → returns (dest_slot (T*k,), keep (T*k,)) where
    dest_slot ∈ [0, E*C) is the flat dispatch-buffer row. Dropped (overflow)
    pairs get keep=False and an arbitrary in-range slot.
    """
    flat = expert_ids.reshape(-1)  # (T*k,)
    tk = flat.shape[0]
    order = jnp.argsort(flat, stable=True)  # token-expert pairs grouped by expert
    sorted_e = flat[order]
    counts = jax.ops.segment_sum(jnp.ones_like(flat, jnp.int32), flat, num_segments=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - starts[sorted_e]
    # undo the sort: position for pair i
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < capacity
    dest = flat * capacity + jnp.minimum(pos, capacity - 1)
    return dest, keep


def moe_apply(
    params,
    cfg: MoEConfig,
    x: jnp.ndarray,  # (..., d)
    *,
    dispatch_constraint=None,
    token_constraint=None,
) -> tuple[jnp.ndarray, dict]:
    """Sparse-dispatch MoE forward. Returns (y, aux_losses).

    ``dispatch_constraint``: optional fn applied to the (E, C, d) buffers
    (``lax.with_sharding_constraint`` under pjit → EP all-to-all).
    ``token_constraint``: optional fn applied to the flat per-pair
    (T·k, d) tensors — without it XLA is free to replicate the gathered
    token copies across the mesh, which at kimi scale is a 120 GB tensor
    per layer (§Perf iteration 1).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)  # (T, d)
    t = xt.shape[0]

    logits = xt.astype(jnp.float32) @ params["router"]  # (T, E)
    expert_ids, gates, aux = router_topk(logits, cfg.top_k)

    capacity = int(np.ceil(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    capacity = max(capacity, 1)
    dest, keep = dispatch_indices(expert_ids, cfg.n_experts, capacity)

    # scatter tokens into (E*C, d); dropped pairs contribute zero
    token_of_pair = jnp.repeat(jnp.arange(t, dtype=jnp.int32), cfg.top_k)
    src = xt[token_of_pair] * keep[:, None].astype(xt.dtype)
    if token_constraint is not None:
        src = token_constraint(src)
    buf = jnp.zeros((cfg.n_experts * capacity, d), xt.dtype).at[dest].add(src)
    buf = buf.reshape(cfg.n_experts, capacity, d)
    if dispatch_constraint is not None:
        buf = dispatch_constraint(buf)

    # per-expert SwiGLU FFN
    h = swiglu(
        jnp.einsum("ecd,edf->ecf", buf, params["e_gate"]),
        jnp.einsum("ecd,edf->ecf", buf, params["e_up"]),
    )
    out = jnp.einsum("ecf,efd->ecd", h, params["e_down"])
    if dispatch_constraint is not None:
        out = dispatch_constraint(out)
    out = out.reshape(cfg.n_experts * capacity, d)

    # combine: gather each pair's expert output, weight by gate
    pair_out = out[dest] * (gates.reshape(-1) * keep.astype(jnp.float32))[:, None].astype(out.dtype)
    if token_constraint is not None:
        pair_out = token_constraint(pair_out)
    y = jax.ops.segment_sum(pair_out, token_of_pair, num_segments=t)

    if cfg.n_shared_experts:
        y = y + (swiglu(xt @ params["s_gate"], xt @ params["s_up"]) @ params["s_down"])

    return y.reshape(orig_shape).astype(x.dtype), aux


def moe_apply_grouped(
    params,
    cfg: MoEConfig,
    x: jnp.ndarray,  # (..., d)
    n_groups: int,
    *,
    dispatch_constraint=None,
    token_constraint=None,
) -> tuple[jnp.ndarray, dict]:
    """Group-local sparse dispatch (per-device-capacity MoE).

    §Perf iteration 2: the global scatter in :func:`moe_apply` partial-sums
    the whole (E·C, d) dispatch buffer across the data axis — XLA lowers it
    as scatter + full-buffer all-reduce (measured: the dominant collective
    of both MoE train cells). Grouping tokens by their data shard and
    scattering *within* the group turns it into a batched scatter over a
    dp-sharded leading axis: the only cross-device movement left is the
    EP exchange implied by the (group, expert, cap, d) → expert-sharded
    einsum, which is the all-to-all a production MoE actually performs.

    Capacity is per-group (ceil(T_g·k/E·cf)) — the per-device capacity
    semantics of real deployments (slightly different drop pattern than the
    global formulation; covered by capacity_factor).

    ``dispatch_constraint`` receives the (G, E, C_g, d) buffers;
    ``token_constraint`` the (G, T_g·k, d) pair tensors.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    if t % n_groups:
        raise ValueError(f"tokens {t} not divisible by n_groups {n_groups}")
    tg = t // n_groups
    xg = xt.reshape(n_groups, tg, d)

    logits = xg.astype(jnp.float32) @ params["router"]  # (G, Tg, E)
    expert_ids, gates, aux = router_topk(logits.reshape(t, cfg.n_experts), cfg.top_k)
    expert_ids = expert_ids.reshape(n_groups, tg * cfg.top_k // cfg.top_k, cfg.top_k)
    gates = gates.reshape(n_groups, tg, cfg.top_k)

    capacity = max(int(np.ceil(tg * cfg.top_k / cfg.n_experts * cfg.capacity_factor)), 1)
    dest, keep = jax.vmap(
        lambda ids: dispatch_indices(ids, cfg.n_experts, capacity)
    )(expert_ids)  # (G, Tg·k) each

    token_of_pair = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), cfg.top_k)  # per group
    src = jnp.take_along_axis(
        xg, jnp.broadcast_to(token_of_pair[None, :, None], (n_groups, tg * cfg.top_k, 1)), axis=1
    ) * keep[..., None].astype(xt.dtype)  # (G, Tg·k, d)
    if token_constraint is not None:
        src = token_constraint(src)

    def group_scatter(dest_g, src_g):
        return jnp.zeros((cfg.n_experts * capacity, d), src_g.dtype).at[dest_g].add(src_g)

    buf = jax.vmap(group_scatter)(dest, src)  # (G, E·C, d)
    buf = buf.reshape(n_groups, cfg.n_experts, capacity, d)
    if dispatch_constraint is not None:
        buf = dispatch_constraint(buf)

    h = swiglu(
        jnp.einsum("gecd,edf->gecf", buf, params["e_gate"]),
        jnp.einsum("gecd,edf->gecf", buf, params["e_up"]),
    )
    out = jnp.einsum("gecf,efd->gecd", h, params["e_down"])
    if dispatch_constraint is not None:
        out = dispatch_constraint(out)
    out = out.reshape(n_groups, cfg.n_experts * capacity, d)

    pair_out = jnp.take_along_axis(
        out, jnp.broadcast_to(dest[..., None], (*dest.shape, d)), axis=1
    )  # (G, Tg·k, d)
    pair_out = pair_out * (
        gates.reshape(n_groups, -1) * keep.astype(jnp.float32)
    )[..., None].astype(out.dtype)
    if token_constraint is not None:
        pair_out = token_constraint(pair_out)
    y = jax.vmap(
        lambda p: jax.ops.segment_sum(p, token_of_pair, num_segments=tg)
    )(pair_out)  # (G, Tg, d)

    y = y.reshape(t, d)
    if cfg.n_shared_experts:
        y = y + (swiglu(xt @ params["s_gate"], xt @ params["s_up"]) @ params["s_down"])
    return y.reshape(orig_shape).astype(x.dtype), aux


def moe_param_count(cfg: MoEConfig) -> int:
    n = cfg.d_model * cfg.n_experts  # router
    n += 3 * cfg.n_experts * cfg.d_model * cfg.d_ff
    if cfg.n_shared_experts:
        n += 3 * cfg.d_model * cfg.d_ff * cfg.n_shared_experts
    return n


def moe_active_param_count(cfg: MoEConfig) -> int:
    n = cfg.d_model * cfg.n_experts
    n += 3 * cfg.top_k * cfg.d_model * cfg.d_ff
    if cfg.n_shared_experts:
        n += 3 * cfg.d_model * cfg.d_ff * cfg.n_shared_experts
    return n
