"""Model building blocks: norms, linear, RoPE, GQA attention, gated FFN.

Conventions (used across the whole model zoo):

* Parameters are plain pytrees (nested dicts of jnp arrays); every module is
  an ``init_*`` + ``apply``-style pure function pair. No framework deps.
* ``param_dtype`` is the storage dtype, ``compute_dtype`` the math dtype
  (bf16 on TPU); norms/softmax accumulate in f32.
* Attention comes in two interchangeable impls: ``"xla"`` (einsum + online
  q-block chunking, SPMD-shardable — the dry-run/roofline path) and
  ``"pallas"`` (kernels/flash_attention — the TPU hot path, validated in
  interpret mode). Both share this module's RoPE/GQA layout: q ``(B,S,H,dh)``,
  kv ``(B,S,Hk,dh)`` with H = Hk * group_size.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# Init helpers                                                                 #
# --------------------------------------------------------------------------- #
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    """Variance-scaling (fan-in) init for a (d_in, d_out) matrix."""
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# Norms                                                                        #
# --------------------------------------------------------------------------- #
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    """RMSNorm with f32 accumulation (LLaMA-style)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# RoPE                                                                         #
# --------------------------------------------------------------------------- #
def rope_frequencies(d_head: int, theta: float = 10_000.0) -> jnp.ndarray:
    """Inverse frequencies, shape (d_head // 2,)."""
    if d_head % 2:
        raise ValueError("RoPE requires even head dim")
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x[..., ::2], x[..., 1::2]) by position*freq.

    x: (B, S, H, dh); positions: (B, S) or (S,) int32.
    """
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B, S, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, dh/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# GQA attention (XLA path)                                                     #
# --------------------------------------------------------------------------- #
def _gqa_scores_einsum(q, k):
    """q (B,Sq,Hk,G,dh), k (B,Skv,Hk,dh) → scores (B,Hk,G,Sq,Skv) in f32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def gqa_attention(
    q: jnp.ndarray,  # (B, Sq, H, dh)
    k: jnp.ndarray,  # (B, Skv, Hk, dh)
    v: jnp.ndarray,  # (B, Skv, Hk, dh)
    *,
    causal: bool = True,
    q_offset: jnp.ndarray | int = 0,
    kv_length: jnp.ndarray | None = None,
    q_block: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Grouped-query attention, f32 softmax, optional q-block chunking.

    ``q_offset``: absolute position of q[:, 0] (prefill continuation/decode).
    ``kv_length``: (B,) valid KV prefix lengths (decode against a cache).
    ``q_block``: chunk queries through a lax.scan so the (Sq, Skv) score
    matrix never materializes beyond (q_block, Skv) — the XLA-path analogue
    of flash attention's memory behaviour (prefill_32k would otherwise
    allocate O(S²)).
    """
    b, sq, h, dh = q.shape
    _, skv, hk, _ = k.shape
    if h % hk:
        raise ValueError(f"q heads {h} not divisible by kv heads {hk}")
    g = h // hk
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(b, sq, hk, g, dh)

    def attend(q_chunk, chunk_offset):
        # q_chunk: (B, Sc, Hk, G, dh); chunk_offset: scalar abs pos of row 0
        scores = _gqa_scores_einsum(q_chunk * scale, k)  # (B,Hk,G,Sc,Skv) f32
        kv_pos = jnp.arange(skv)
        mask = None
        if causal:
            q_pos = chunk_offset + jnp.arange(q_chunk.shape[1])
            mask = kv_pos[None, :] <= q_pos[:, None]  # (Sc, Skv)
            mask = mask[None, None, None]
        if kv_length is not None:
            len_mask = kv_pos[None, :] < kv_length[:, None]  # (B, Skv)
            len_mask = len_mask[:, None, None, None, :]
            mask = len_mask if mask is None else (mask & len_mask)
        if mask is not None:
            scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        # guard fully-masked rows (all -inf → nan)
        probs = jnp.where(jnp.isnan(probs), 0.0, probs)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
        return out.reshape(b, q_chunk.shape[1], h, dh)

    if q_block is None or q_block >= sq:
        return attend(qg, jnp.asarray(q_offset))

    if sq % q_block:
        raise ValueError(f"seq len {sq} not divisible by q_block {q_block}")
    n_chunks = sq // q_block
    qs = qg.reshape(b, n_chunks, q_block, hk, g, dh)
    # Unrolled (Python) chunk loop: XLA reuses the chunk buffers across the
    # sequential ops (same memory behaviour as a scan) but cost_analysis and
    # the backward pass see every chunk — a nested scan would undercount
    # FLOPs by n_chunks in the roofline accounting. Each chunk is
    # checkpointed so the backward recomputes its probs instead of keeping
    # every chunk's (bq × Skv) matrix live — flash-attention's recompute
    # semantics, expressed at the XLA level.
    attend_ckpt = jax.checkpoint(attend, static_argnums=())
    outs = [
        attend_ckpt(qs[:, i], jnp.asarray(q_offset) + i * q_block) for i in range(n_chunks)
    ]
    return jnp.concatenate(outs, axis=1)


# --------------------------------------------------------------------------- #
# FFN activations                                                              #
# --------------------------------------------------------------------------- #
def swiglu(x_gate: jnp.ndarray, x_up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(x_gate) * x_up


ACTIVATIONS: dict[str, Callable] = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    "silu": jax.nn.silu,
}


def mlp_init(key, sizes: list[int], dtype=jnp.float32, bias: bool = True):
    """Plain MLP params for [d0, d1, ..., dn] layer sizes."""
    keys = jax.random.split(key, len(sizes) - 1)
    layers = []
    for i, k in enumerate(keys):
        layer = {"w": dense_init(k, sizes[i], sizes[i + 1], dtype)}
        if bias:
            layer["b"] = jnp.zeros((sizes[i + 1],), dtype)
        layers.append(layer)
    return {"layers": layers}


def mlp_apply(params, x, *, activation: str = "relu", final_activation: bool = False):
    n = len(params["layers"])
    act = ACTIVATIONS[activation]
    for i, layer in enumerate(params["layers"]):
        x = x @ layer["w"]
        if "b" in layer:
            x = x + layer["b"]
        if i < n - 1 or final_activation:
            x = act(x)
    return x
