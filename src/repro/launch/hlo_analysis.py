"""Roofline-term extraction from compiled XLA artifacts.

Sources (per assignment §ROOFLINE):
* ``compiled.cost_analysis()`` → HLO FLOPs + bytes accessed (per device —
  the post-SPMD module is the per-device program),
* ``compiled.as_text()`` → collective operand bytes, parsed per op kind
  (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute), per-device shapes.

Terms (seconds), v5e constants from configs.base:
    compute    = flops_per_device / PEAK_FLOPS_BF16
    memory     = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW
"""

from __future__ import annotations

import dataclasses
import re

from repro.configs.base import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# matches e.g.  bf16[256,4096,6144]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum output-operand bytes of every collective op, by kind.

    Operates on post-optimization per-device HLO: each line defining a
    collective looks like ``%x = TYPE[dims]{layout} all-reduce(...)`` or a
    tuple ``%x = (T1[..], T2[..]) all-gather(...)``.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "fusion" in stripped.split("(")[0] and not any(
            f" {c}(" in stripped or f"{c}-start(" in stripped for c in _COLLECTIVES
        ):
            continue
        for kind in _COLLECTIVES:
            # match "= <shapes> kind(" and async "-start(" forms; skip -done
            # (same bytes would double-count)
            marker_plain = f" {kind}("
            marker_start = f" {kind}-start("
            if marker_plain in stripped or marker_start in stripped:
                lhs = stripped.split(f" {kind}", 1)[0]
                if "=" not in lhs:
                    continue
                shapes_part = lhs.split("=", 1)[1]
                nbytes = sum(
                    _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(shapes_part)
                )
                out[kind] += nbytes
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    model_flops_total: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def hlo_flops_total(self) -> float:
        return self.flops_per_device * self.n_devices

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops_total / max(self.hlo_flops_total, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the bound: what fraction of the
        dominant term's time the *model* FLOPs would ideally need."""
        ideal = (self.model_flops_total / self.n_devices) / PEAK_FLOPS_BF16
        return ideal / max(self.bound_s, 1e-30)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "n_devices": self.n_devices,
            "model_flops_total": self.model_flops_total,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze_compiled(
    compiled,
    n_devices: int,
    model_flops: float,
    *,
    extra_flops: float = 0.0,
    extra_bytes: float = 0.0,
    extra_collective: float = 0.0,
) -> tuple[RooflineTerms, dict]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) + extra_flops
    byts = float(cost.get("bytes accessed", 0.0)) + extra_bytes
    coll = collective_bytes_from_hlo(compiled.as_text())
    terms = RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=float(coll["total"]) + extra_collective,
        n_devices=n_devices,
        model_flops_total=model_flops,
    )
    mem = compiled.memory_analysis()
    memory = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    return terms, {"collectives": coll, "memory": memory}
