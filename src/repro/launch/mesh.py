"""Production mesh construction (assignment-specified).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state. Single-pod: 16×16 =
256 chips, axes (data, model). Multi-pod: 2×16×16 = 512 chips, axes
(pod, data, model) — the pod axis is the slower DCN/ICI dimension that
gradient all-reduce crosses.
"""

from __future__ import annotations

import jax

try:  # AxisType landed after 0.4.x; older jax is implicitly Auto everywhere
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_smoke_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests / CPU)."""
    n = n_devices or len(jax.devices())
    return _mesh((1, n), ("data", "model"))
