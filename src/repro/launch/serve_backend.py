"""Retrieval-as-a-service entry point: serve one backend over a socket.

    PYTHONPATH=src python -m repro.launch.serve_backend \
        --backend dense --port 8631

Pairs with ``python -m repro.launch.serve --remote-backend dense=HOST:PORT``
on the client side: the serving engine's backend map gets a
:class:`~repro.retrieval.remote.RemoteBackend` RPC client in place of the
named backend, and every client-side decorator (cache, faults, resilience)
wraps the network hop unchanged. The service can itself shard — ``--shards``
builds the served backend through the same declarative stack the engine
uses, so a remote dense backend can fan out across shards server-side.
"""

from __future__ import annotations

import argparse


def build_served_backend(args: argparse.Namespace):
    """Build the one backend this process serves (corpus + optional shards)."""
    from repro.retrieval import (
        BackendStackConfig,
        DenseIndex,
        HashedNGramEmbedder,
        build_backend_stack,
        line_passages,
        make_backends,
    )

    if args.synthetic_docs > 0:
        if args.docs:
            raise SystemExit("--synthetic-docs and --docs are mutually exclusive")
        from repro.retrieval import synthetic_dense_index

        embedder = HashedNGramEmbedder(dim=args.synthetic_dim)
        index = synthetic_dense_index(
            args.synthetic_docs, args.synthetic_dim, seed=args.synthetic_seed
        )
        passages = index.passages
    else:
        from repro.data.benchmark import corpus_document

        doc = open(args.docs).read() if args.docs else corpus_document()
        embedder = HashedNGramEmbedder(dim=256)
        passages = line_passages(doc)
        index, _ = DenseIndex.build(passages, embedder)

    names = ("dense",) if args.backend == "dense" else ("dense", args.backend)
    backends = make_backends(index, passages, embedder, names=names)
    if args.shards > 1:
        stack = BackendStackConfig(
            shards=args.shards,
            shard_execution=args.shard_execution,
            shard_backends=(args.backend,),
        )
        backends = build_backend_stack(backends, stack, index=index)
    return backends[args.backend]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--backend", default="dense", choices=("dense", "bm25", "ivf", "hybrid"),
        help="which retrieval backend this service exposes (default dense)",
    )
    ap.add_argument("--docs", default=None,
                    help="newline-separated passages (default: paper corpus)")
    ap.add_argument(
        "--synthetic-docs", type=int, default=0, metavar="N",
        help="serve a seeded synthetic corpus of N documents instead of "
        "--docs (systems benchmarking; mutually exclusive with --docs)",
    )
    ap.add_argument("--synthetic-dim", type=int, default=64, metavar="D",
                    help="embedding dimension for --synthetic-docs")
    ap.add_argument("--synthetic-seed", type=int, default=0,
                    help="RNG seed for the --synthetic-docs corpus")
    ap.add_argument(
        "--shards", type=int, default=1, metavar="S",
        help="shard the served backend S ways server-side (bit-identical; "
        "this is where sharding lives when the client uses --remote-backend)",
    )
    ap.add_argument(
        "--shard-execution", default="threads",
        choices=("threads", "process", "device", "auto"),
        help="shard fan-out execution for --shards (see serve --help)",
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8631,
                    help="listening port (0 binds an ephemeral port)")
    ap.add_argument(
        "--format", default=None, choices=("msgpack", "json"),
        help="wire encoding (default: msgpack when importable, else json)",
    )
    args = ap.parse_args()

    from repro.retrieval.remote import BackendServer

    backend = build_served_backend(args)
    server = BackendServer(backend, host=args.host, port=args.port, fmt=args.format)
    print(
        f"serving backend {backend.name!r} ({backend.size} passages) "
        f"on {server.host}:{server.port} [{server.fmt}] — "
        f"connect with: --remote-backend {args.backend}={server.host}:{server.port}"
    )
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        server.stop()


if __name__ == "__main__":
    main()
