"""CA-RAG serving entry point: route → retrieve → generate over a query file.

    PYTHONPATH=src python -m repro.launch.serve \
        --docs data/documents.txt --questions data/questions.txt \
        --policy router_default --out results/serve.csv

Defaults reproduce the paper benchmark exactly (Appendix D/E artifacts).
"""

from __future__ import annotations

import argparse


def build_engine_from_opts(opts: dict) -> "object":
    """Build the serving engine from a plain-dict option bag.

    Module-level, driven purely by picklable primitives (paths, numbers,
    ``NAME=VAL`` strings), so ``functools.partial(build_engine_from_opts,
    opts)`` is a spawn-safe engine factory: ``--executor process`` workers
    rebuild the exact engine — corpus, backend stack, fault schedules,
    guardrails — the parent serves, which is what keeps worker-computed
    middle stages bit-identical to the parent's replay.

    Raises ``SystemExit`` with a CLI-shaped message on invalid options
    (the parent always validates first, so workers never see these).
    """
    from repro.core.bundles import make_catalog
    from repro.core.guardrails import GuardrailConfig
    from repro.core.policies import make_policy
    from repro.core.router import RouterConfig
    from repro.data.benchmark import corpus_document
    from repro.retrieval import (
        BackendStackConfig,
        DenseIndex,
        FaultProfile,
        HashedNGramEmbedder,
        build_backend_stack,
        line_passages,
        make_backends,
    )
    from repro.serving.engine import EngineConfig, RAGEngine

    catalog = make_catalog(opts["catalog"])
    router = make_policy(
        opts["policy"], catalog=catalog, config=RouterConfig(epsilon=opts["epsilon"])
    )
    if opts["synthetic_docs"] > 0:
        if opts["docs"]:
            raise SystemExit("--synthetic-docs and --docs are mutually exclusive")
        from repro.retrieval import synthetic_dense_index

        embedder = HashedNGramEmbedder(dim=opts["synthetic_dim"])
        index = synthetic_dense_index(
            opts["synthetic_docs"], opts["synthetic_dim"], seed=opts["synthetic_seed"]
        )
        passages = index.passages
        index_tokens = 0  # nothing was embedded: the corpus is fabricated
    else:
        doc = open(opts["docs"]).read() if opts["docs"] else corpus_document()
        embedder = HashedNGramEmbedder(dim=256)
        passages = line_passages(doc)
        index, index_tokens = DenseIndex.build(passages, embedder)
    backends = make_backends(
        index, passages, embedder, names=("dense", *catalog.backends_used())
    )

    fault_profiles: dict[str, FaultProfile] = {}
    for spec in opts["fault_profile"]:
        try:
            name, profile = FaultProfile.parse(spec)
        except ValueError as err:
            raise SystemExit(f"--fault-profile: {err}")
        if name not in backends:
            raise SystemExit(
                f"--fault-profile: unknown backend {name!r} "
                f"(this catalog serves {sorted(backends)})"
            )
        fault_profiles[name] = profile
    remote_backends: dict[str, str] = {}
    for item in opts["remote_backend"]:
        name, sep, addr = item.partition("=")
        if not sep or not name or not addr:
            raise SystemExit(
                f"--remote-backend expects NAME=HOST:PORT, got {item!r}"
            )
        remote_backends[name] = addr
    resilience: object = None
    if (
        opts["retrieve_timeout_ms"] is not None
        or opts["max_retries"] is not None
        or fault_profiles
    ):
        from repro.serving.resilience import ResilienceConfig, RetryPolicy

        resilience = ResilienceConfig(
            timeout_ms=opts["retrieve_timeout_ms"],
            retry=RetryPolicy(
                max_retries=opts["max_retries"] if opts["max_retries"] is not None else 2
            ),
        )
    # One declarative recipe for the whole decorator stack — ordering
    # (remote → shard → faults → cache → resilience) lives in
    # build_backend_stack, not here.
    try:
        stack = BackendStackConfig(
            shards=opts["shards"],
            shard_execution=opts["shard_execution"],
            shard_backends=tuple(
                n.strip() for n in opts["shard_backends"].split(",") if n.strip()
            ),
            remote_backends=remote_backends,
            cache_size=opts["cache_size"],
            fault_profiles=fault_profiles,
            resilience=resilience,
        )
    except ValueError as err:
        raise SystemExit(f"invalid backend stack: {err}")
    backends = build_backend_stack(backends, stack, index=index)

    per_backend_conf: dict[str, float] = {}
    for item in opts["min_confidence_backend"]:
        name, sep, val = item.partition("=")
        try:
            threshold = float(val)
        except ValueError:
            threshold = None
        if not sep or not name or threshold is None:
            raise SystemExit(
                f"--min-confidence-backend expects NAME=VAL, got {item!r}"
            )
        if name not in backends:
            # a typo here would silently fall back to the global threshold —
            # exactly the guardrail hole the flag exists to close
            raise SystemExit(
                f"--min-confidence-backend: unknown backend {name!r} "
                f"(this catalog serves {sorted(backends)})"
            )
        per_backend_conf[name] = threshold

    return RAGEngine(
        router,
        index,
        embedder,
        catalog=router.catalog,
        backends=backends,
        config=EngineConfig(
            guardrails=GuardrailConfig(
                min_retrieval_confidence=opts["min_confidence"],
                max_cost_tokens=opts["max_cost_tokens"],
                min_retrieval_confidence_by_backend=per_backend_conf or None,
            )
        ),
        index_embedding_tokens=index_tokens,
    )


_ENGINE_OPT_KEYS = (
    "docs", "policy", "catalog", "epsilon", "min_confidence",
    "min_confidence_backend", "max_cost_tokens", "cache_size", "shards",
    "shard_backends", "shard_execution", "remote_backend", "synthetic_docs",
    "synthetic_dim", "synthetic_seed", "fault_profile", "retrieve_timeout_ms",
    "max_retries",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", default=None, help="newline-separated passages (default: paper corpus)")
    ap.add_argument("--questions", default=None, help="one query per line (default: paper queries)")
    ap.add_argument("--policy", default="router_default")
    ap.add_argument(
        "--catalog", default="paper", choices=("paper", "extended"),
        help="bundle catalog preset: 'paper' = Table I (dense-only, parity-"
        "pinned); 'extended' adds BM25-light / IVF-medium / hybrid-heavy "
        "bundles routed through the pluggable retrieval backends",
    )
    ap.add_argument("--out", default="results/serve.csv")
    ap.add_argument("--epsilon", type=float, default=0.0)
    ap.add_argument("--min-confidence", type=float, default=0.0)
    ap.add_argument(
        "--min-confidence-backend", action="append", default=[], metavar="NAME=VAL",
        help="per-backend low-confidence threshold override (repeatable), "
        "e.g. --min-confidence-backend bm25=2.5 — confidence units differ "
        "per backend (docs/retrieval.md), so lexical bundles need their own "
        "scale; 0 disables the guardrail for that backend",
    )
    ap.add_argument("--max-cost-tokens", type=int, default=None)
    ap.add_argument(
        "--cache-size", type=int, default=0, metavar="N",
        help="wrap every retrieval backend in an exact query-result LRU of N "
        "entries (0 = no caching); repeated queries are served at memory "
        "speed with bit-identical results",
    )
    ap.add_argument(
        "--shards", type=int, default=1, metavar="S",
        help="partition the dense corpus across S shards (bit-identical to "
        "unsharded). 1 = single index",
    )
    ap.add_argument(
        "--shard-backends", default="dense", metavar="NAMES",
        help="comma-separated backend names --shards partitions (default "
        "'dense'). Adding bm25/ivf shards those too — replicated global "
        "idf/avgdl and centroid stats keep results bit-identical; sparse "
        "methods always shard on the threads path (--shard-execution "
        "governs dense only)",
    )
    ap.add_argument(
        "--shard-execution", default="threads",
        choices=("threads", "process", "device", "auto"),
        help="how sharded search runs: 'threads' fans per-shard searches out "
        "on host threads; 'process' fans out to persistent per-shard worker "
        "processes (GIL-free — the multi-core host path); 'device' lowers "
        "search + top-k merge onto the jax device mesh as one shard_map "
        "program (requires >= S devices; on CPU hosts set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=S); 'auto' picks "
        "inline threads or process by core count. All are bit-identical to "
        "unsharded retrieval (docs/retrieval.md)",
    )
    ap.add_argument(
        "--remote-backend", action="append", default=[], metavar="NAME=HOST:PORT",
        help="serve backend NAME through a remote retrieval service "
        "(repeatable), e.g. --remote-backend dense=127.0.0.1:8631 — the "
        "named backend is replaced by a RemoteBackend RPC client; start the "
        "service with python -m repro.launch.serve_backend. Cache/"
        "resilience layers wrap the remote client unchanged",
    )
    ap.add_argument(
        "--synthetic-docs", type=int, default=0, metavar="N",
        help="replace the corpus with N seeded synthetic documents (random "
        "unit embeddings + placeholder passages) — the retrieval-scaling "
        "configuration: quality is meaningless, systems behaviour "
        "(sharding, caching, latency) is real. Mutually exclusive with "
        "--docs; 0 = use the real corpus",
    )
    ap.add_argument(
        "--synthetic-dim", type=int, default=64, metavar="D",
        help="embedding dimension for --synthetic-docs (default 64; a "
        "million-doc corpus at D=64 is ~256 MB of float32)",
    )
    ap.add_argument(
        "--synthetic-seed", type=int, default=0,
        help="RNG seed for the --synthetic-docs corpus (same seed = "
        "bit-identical corpus)",
    )
    ap.add_argument(
        "--fault-profile", action="append", default=[], metavar="NAME:K=V,...",
        help="inject a seeded fault schedule into backend NAME (repeatable), "
        "e.g. --fault-profile dense:failure_rate=0.3,stall_every=6,"
        "stall_ms=1500,seed=2 — keys are FaultProfile fields; pair with "
        "--retrieve-timeout-ms/--max-retries to exercise the resilience "
        "ladder (docs/resilience.md)",
    )
    ap.add_argument(
        "--retrieve-timeout-ms", type=float, default=None, metavar="MS",
        help="per-search_batch timeout; a timed-out call counts as a failure "
        "and is retried. Enables the ResilientBackend wrapper (with retries, "
        "circuit breaker, and the degradation ladder) even at 0 retries",
    )
    ap.add_argument(
        "--max-retries", type=int, default=None, metavar="N",
        help="bounded seeded-backoff retries per retrieval call (default 2 "
        "when resilience is active); enables the ResilientBackend wrapper",
    )
    ap.add_argument(
        "--request-deadline-ms", type=float, default=None, metavar="MS",
        help="per-request wall-clock deadline from arrival (--stream only); "
        "requests already late at admission get a typed deadline_exceeded "
        "rejection instead of burning decode slots",
    )
    ap.add_argument(
        "--scenario", default=None, metavar="NAME",
        help="run a named workload scenario from the declarative suite "
        "(serving/scenarios.py) instead of the query file: corpus, stream, "
        "engine stack, and SLO targets all come from the seeded spec; "
        "prints the scenario's JSON cell and writes telemetry to --out. "
        "Mutually exclusive with --stream/--docs/--questions",
    )
    ap.add_argument(
        "--scenario-scale", type=float, default=1.0, metavar="X",
        help="scale the scenario's stream lengths and intake caps by X "
        "(--scenario only; the gated counters only hold at 1)",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="serve from a live Poisson arrival queue (retrieval/decode overlap) "
        "instead of one pre-collected batch",
    )
    ap.add_argument("--rate-qps", type=float, default=0.0,
                    help="offered load for --stream; <=0 means all arrive at t=0")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="micro-batches in flight through the stage pipeline "
                    "(--stream only; 1 = fully serial)")
    ap.add_argument("--retrieval-workers", type=int, default=1,
                    help="workers draining the retrieve/assemble/decode "
                    "stages (--stream only; ignored at depth 1)")
    ap.add_argument(
        "--executor", default="thread", choices=("thread", "process"),
        help="where the pipeline's middle stages run (--stream only): "
        "'thread' = in-process worker threads (GIL-bound); 'process' = "
        "spawn-context worker processes that each rebuild this engine once "
        "and drain micro-batches GIL-free. Records are bit-identical "
        "either way (docs/serving.md)",
    )
    ap.add_argument("--tokens-per-s", type=float, default=None,
                    help="pace the slot decoder's step clock (--stream only; "
                    "default: free-running)")
    ap.add_argument("--seed", type=int, default=0, help="arrival-trace seed (--stream)")
    args = ap.parse_args()

    if args.scenario is not None:
        import json

        if args.stream or args.docs or args.questions:
            ap.error("--scenario is mutually exclusive with --stream/--docs/--questions")
        from repro.serving.scenarios import SCENARIOS, run_scenario

        spec = SCENARIOS.get(args.scenario)
        if spec is None:
            ap.error(
                f"unknown scenario {args.scenario!r}; "
                f"available: {', '.join(sorted(SCENARIOS))}"
            )
        result = run_scenario(spec, scale=args.scenario_scale)
        print(json.dumps({args.scenario: result.cell}, indent=2))
        # telemetry CSV comes from the scenario's own engine — the records
        # behind the cell's completed/degraded counters
        telemetry = result.engine.telemetry
        telemetry.to_csv(args.out)
        print(f"wrote {len(telemetry.records)} records to {args.out}")
        return

    from repro.data.benchmark import BENCHMARK_QUERIES, REFERENCE_ANSWERS

    if args.questions:
        with open(args.questions) as f:
            queries = [line.strip() for line in f if line.strip()]
        references = None
    else:
        queries = list(BENCHMARK_QUERIES)
        references = list(REFERENCE_ANSWERS)

    opts = {key: getattr(args, key) for key in _ENGINE_OPT_KEYS}
    engine = build_engine_from_opts(opts)
    catalog = engine.catalog
    if args.stream:
        import functools
        import json
        import math

        from repro.serving.generator import TransformerSlotDecoder
        from repro.serving.streaming import StreamConfig, serve_stream

        depth = args.pipeline_depth
        decoder = TransformerSlotDecoder.tiny(n_slots=8, tokens_per_s=args.tokens_per_s)
        decoder.warmup()  # decode-step compile must not bill to the first batch's TTFT
        result = serve_stream(
            engine,
            queries,
            references,
            rate_qps=args.rate_qps if args.rate_qps > 0 else math.inf,
            seed=args.seed,
            decode_fn=decoder,
            config=StreamConfig(
                overlap=depth > 1,
                pipeline_depth=depth,
                retrieval_workers=args.retrieval_workers,
                executor=args.executor,
                request_deadline_ms=args.request_deadline_ms,
            ),
            # spawn-safe: workers rebuild this exact engine from the same
            # plain-dict options the parent used
            engine_factory=functools.partial(build_engine_from_opts, opts),
        )
        print(json.dumps(result.summary(), indent=2))
        if result.rejections:
            print(f"rejected {len(result.rejections)} requests "
                  f"(first: {result.rejections[0].reason})")
    telemetry = engine.telemetry if args.stream else engine.run(queries, references)
    telemetry.to_csv(args.out)
    print(telemetry.summary_json())
    if args.catalog != "paper":
        # (backend × depth) routing view: which retrieval method served what
        print(f"routed by backend: {catalog.routed_by_backend(telemetry.strategy_counts())}")
    if args.cache_size > 0:
        from repro.retrieval import cache_stats_view

        print(f"backend cache: {cache_stats_view(engine.backends)}")
    print(f"wrote {len(telemetry.records)} records to {args.out}")


if __name__ == "__main__":
    main()
