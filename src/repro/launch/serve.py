"""CA-RAG serving entry point: route → retrieve → generate over a query file.

    PYTHONPATH=src python -m repro.launch.serve \
        --docs data/documents.txt --questions data/questions.txt \
        --policy router_default --out results/serve.csv

Defaults reproduce the paper benchmark exactly (Appendix D/E artifacts).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", default=None, help="newline-separated passages (default: paper corpus)")
    ap.add_argument("--questions", default=None, help="one query per line (default: paper queries)")
    ap.add_argument("--policy", default="router_default")
    ap.add_argument(
        "--catalog", default="paper", choices=("paper", "extended"),
        help="bundle catalog preset: 'paper' = Table I (dense-only, parity-"
        "pinned); 'extended' adds BM25-light / IVF-medium / hybrid-heavy "
        "bundles routed through the pluggable retrieval backends",
    )
    ap.add_argument("--out", default="results/serve.csv")
    ap.add_argument("--epsilon", type=float, default=0.0)
    ap.add_argument("--min-confidence", type=float, default=0.0)
    ap.add_argument(
        "--min-confidence-backend", action="append", default=[], metavar="NAME=VAL",
        help="per-backend low-confidence threshold override (repeatable), "
        "e.g. --min-confidence-backend bm25=2.5 — confidence units differ "
        "per backend (docs/retrieval.md), so lexical bundles need their own "
        "scale; 0 disables the guardrail for that backend",
    )
    ap.add_argument("--max-cost-tokens", type=int, default=None)
    ap.add_argument(
        "--cache-size", type=int, default=0, metavar="N",
        help="wrap every retrieval backend in an exact query-result LRU of N "
        "entries (0 = no caching); repeated queries are served at memory "
        "speed with bit-identical results",
    )
    ap.add_argument(
        "--shards", type=int, default=1, metavar="S",
        help="partition the dense corpus across S shards (fan-out + fused "
        "top-k merge; bit-identical to unsharded). 1 = single index",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="serve from a live Poisson arrival queue (retrieval/decode overlap) "
        "instead of one pre-collected batch",
    )
    ap.add_argument("--rate-qps", type=float, default=0.0,
                    help="offered load for --stream; <=0 means all arrive at t=0")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="micro-batches in flight through the stage pipeline "
                    "(--stream only; 1 = fully serial)")
    ap.add_argument("--retrieval-workers", type=int, default=1,
                    help="worker threads draining the retrieve/assemble/decode "
                    "stages (--stream only; ignored at depth 1)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="deprecated alias for --pipeline-depth 1")
    ap.add_argument("--tokens-per-s", type=float, default=None,
                    help="pace the slot decoder's step clock (--stream only; "
                    "default: free-running)")
    ap.add_argument("--seed", type=int, default=0, help="arrival-trace seed (--stream)")
    args = ap.parse_args()

    import dataclasses

    from repro.core.bundles import make_catalog
    from repro.core.guardrails import GuardrailConfig
    from repro.core.policies import make_policy
    from repro.core.router import RouterConfig
    from repro.data.benchmark import BENCHMARK_QUERIES, REFERENCE_ANSWERS, corpus_document
    from repro.retrieval import DenseIndex, HashedNGramEmbedder, line_passages, make_backends
    from repro.serving.engine import EngineConfig, RAGEngine

    if args.questions:
        with open(args.questions) as f:
            queries = [line.strip() for line in f if line.strip()]
        references = None
    else:
        queries = list(BENCHMARK_QUERIES)
        references = list(REFERENCE_ANSWERS)

    doc = open(args.docs).read() if args.docs else corpus_document()

    catalog = make_catalog(args.catalog)
    router = make_policy(args.policy, catalog=catalog, config=RouterConfig(epsilon=args.epsilon))
    embedder = HashedNGramEmbedder(dim=256)
    passages = line_passages(doc)
    index, index_tokens = DenseIndex.build(passages, embedder)
    backends = make_backends(
        index, passages, embedder, names=("dense", *catalog.backends_used())
    )
    from repro.retrieval import scale_backends

    backends = scale_backends(
        backends, index, cache_size=args.cache_size, shards=args.shards
    )

    per_backend_conf: dict[str, float] = {}
    for item in args.min_confidence_backend:
        name, sep, val = item.partition("=")
        try:
            threshold = float(val)
        except ValueError:
            threshold = None
        if not sep or not name or threshold is None:
            raise SystemExit(
                f"--min-confidence-backend expects NAME=VAL, got {item!r}"
            )
        if name not in backends:
            # a typo here would silently fall back to the global threshold —
            # exactly the guardrail hole the flag exists to close
            raise SystemExit(
                f"--min-confidence-backend: unknown backend {name!r} "
                f"(this catalog serves {sorted(backends)})"
            )
        per_backend_conf[name] = threshold

    engine = RAGEngine(
        router,
        index,
        embedder,
        catalog=router.catalog,
        backends=backends,
        config=EngineConfig(
            guardrails=GuardrailConfig(
                min_retrieval_confidence=args.min_confidence,
                max_cost_tokens=args.max_cost_tokens,
                min_retrieval_confidence_by_backend=per_backend_conf or None,
            )
        ),
        index_embedding_tokens=index_tokens,
    )
    if args.stream:
        import json
        import math

        from repro.serving.generator import TransformerSlotDecoder
        from repro.serving.streaming import StreamConfig, serve_stream

        depth = args.pipeline_depth
        if args.no_overlap:
            print("note: --no-overlap is deprecated; use --pipeline-depth 1")
            depth = 1
        decoder = TransformerSlotDecoder.tiny(n_slots=8, tokens_per_s=args.tokens_per_s)
        decoder.warmup()  # decode-step compile must not bill to the first batch's TTFT
        result = serve_stream(
            engine,
            queries,
            references,
            rate_qps=args.rate_qps if args.rate_qps > 0 else math.inf,
            seed=args.seed,
            decode_fn=decoder,
            config=StreamConfig(
                overlap=depth > 1,
                pipeline_depth=depth,
                retrieval_workers=args.retrieval_workers,
            ),
        )
        print(json.dumps(result.summary(), indent=2))
        if result.rejections:
            print(f"rejected {len(result.rejections)} requests "
                  f"(first: {result.rejections[0].reason})")
    telemetry = engine.telemetry if args.stream else engine.run(queries, references)
    telemetry.to_csv(args.out)
    print(telemetry.summary_json())
    if args.catalog != "paper":
        # (backend × depth) routing view: which retrieval method served what
        print(f"routed by backend: {catalog.routed_by_backend(telemetry.strategy_counts())}")
    if args.cache_size > 0:
        from repro.retrieval import cache_stats_view

        print(f"backend cache: {cache_stats_view(engine.backends)}")
    print(f"wrote {len(telemetry.records)} records to {args.out}")


if __name__ == "__main__":
    main()
