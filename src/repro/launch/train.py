"""Distributed training entry point (LM family).

Production shape: mesh-aware pjit train step, checkpoint/restart supervision,
synthetic sharded data pipeline, straggler/heartbeat wiring. On this CPU
container run it with ``--smoke`` (reduced model, 1 device); on a real
cluster the same script runs the full config against the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", help="reduced config on local devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress", choices=["none", "int8", "topk"], default="none")
    args = ap.parse_args()

    from repro.configs.lm_archs import REGISTRY_CONFIGS
    from repro.models.transformer import TransformerConfig, init_params, loss_fn
    from repro.training.checkpoint import CheckpointManager
    from repro.training.compression import Int8Compressor, TopKCompressor
    from repro.training.data import LMDataConfig, TokenStream
    from repro.training.optimizer import AdamWConfig, make_adamw, warmup_cosine
    from repro.training.train_loop import TrainStepConfig, make_train_step

    cfg = REGISTRY_CONFIGS[args.arch]
    if args.smoke:
        cfg = dataclasses.replace(
            cfg,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            d_head=16,
            d_ff=128,
            vocab=512,
            n_experts=4 if cfg.is_moe else None,
            moe_top_k=2 if cfg.is_moe else 0,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            compute_dtype=jnp.float32,
            param_dtype=jnp.float32,
            max_seq_len=args.seq,
            remat="none",
            q_block=None,
        )

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = make_adamw(
        AdamWConfig(lr=warmup_cosine(args.lr, 5, args.steps), weight_decay=0.01)
    )
    opt_state = opt.init(params)

    compressor = {"none": None, "int8": Int8Compressor(), "topk": TopKCompressor(0.05)}[args.compress]
    residual = compressor.init_residual(params) if compressor else None

    def loss(params, batch):
        return loss_fn(params, cfg, batch["tokens"], batch["targets"])

    step = jax.jit(
        make_train_step(loss, opt, TrainStepConfig(compressor=compressor))
    )

    stream = TokenStream(LMDataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch, seed=0))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    start = 0
    if mgr and mgr.latest_step() is not None:
        state, _ = mgr.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = mgr.latest_step()
        print(f"restored from step {start}")

    it = stream.batches()
    for i, batch in zip(range(start, args.steps), it):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        if compressor:
            params, opt_state, residual, metrics = step(params, opt_state, jb, residual)
        else:
            params, opt_state, metrics = step(params, opt_state, jb)
        dt = time.time() - t0
        print(
            f"step {i:4d} loss={float(metrics['loss']):.4f} "
            f"gnorm={float(metrics['grad_norm']):.3f} lr={float(metrics['lr']):.2e} {dt*1000:.0f}ms"
        )
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
        print(f"final checkpoint at step {args.steps}: {mgr.step_dir(args.steps)}")


if __name__ == "__main__":
    main()
