import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

Per cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the cell's step function + ShapeDtypeStruct inputs + shardings
     (src/repro/configs — no real allocation anywhere),
  3. ``jax.jit(fn, in_shardings=...).lower(*specs).compile()``,
  4. records memory_analysis / cost_analysis / parsed collective bytes and
     the three roofline terms to a JSONL artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-20b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out results/dryrun.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi  --out results/dryrun_multi.jsonl
"""

import argparse
import json
import time
import traceback


def run_cell(arch_name: str, shape: str, multi_pod: bool, *, policy_overrides=None) -> dict:
    import jax

    from repro.configs.base import get_arch, policy_for_mesh
    from repro.launch.hlo_analysis import analyze_compiled
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = 1
    for s in mesh.shape.values():
        n_devices *= s
    policy = policy_for_mesh(mesh, **(policy_overrides or {}))
    arch = get_arch(arch_name)
    cell = arch.cells()[shape]

    t0 = time.time()
    built = cell.build(mesh, policy)
    with mesh:  # PartitionSpec-based with_sharding_constraints need context
        jit_kwargs = {}
        if built.out_shardings is not None:
            jit_kwargs["out_shardings"] = built.out_shardings
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings, **jit_kwargs)
        lowered = jitted.lower(*built.input_specs)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    # scan-body correction: XLA counts while-loop bodies once; add
    # (trip_count - 1) x standalone-body cost (see configs.base.ScanCorrection)
    corr_flops = corr_bytes = corr_coll = 0.0
    with mesh:
        for sc in built.scan_corrections:
            body_compiled = (
                jax.jit(sc.fn, in_shardings=sc.in_shardings).lower(*sc.input_specs).compile()
            )
            c = body_compiled.cost_analysis()
            if isinstance(c, list):
                c = c[0]
            from repro.launch.hlo_analysis import collective_bytes_from_hlo

            coll = collective_bytes_from_hlo(body_compiled.as_text())
            corr_flops += sc.multiplier * float(c.get("flops", 0.0))
            corr_bytes += sc.multiplier * float(c.get("bytes accessed", 0.0))
            corr_coll += sc.multiplier * float(coll["total"])

    terms, extra = analyze_compiled(
        compiled,
        n_devices,
        built.model_flops_per_step,
        extra_flops=corr_flops,
        extra_bytes=corr_bytes,
        extra_collective=corr_coll,
    )
    record = {
        "arch": arch_name,
        "shape": shape,
        "kind": cell.kind,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_devices,
        "description": built.description,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "status": "ok",
        **terms.as_dict(),
        **extra,
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import all_arch_names, get_arch

    if args.all:
        targets = [
            (a, s) for a in all_arch_names() for s in get_arch(a).cells()
        ]
    else:
        if not args.arch or not args.shape:
            raise SystemExit("--arch and --shape required (or --all)")
        targets = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    existing = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    existing.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    for multi in meshes:
        mesh_name = "multi" if multi else "single"
        for arch_name, shape in targets:
            if (arch_name, shape, mesh_name) in existing:
                print(f"SKIP {arch_name} × {shape} × {mesh_name} (already done)")
                continue
            print(f"=== {arch_name} × {shape} × {mesh_name} ===", flush=True)
            try:
                rec = run_cell(arch_name, shape, multi)
                print(
                    f"  ok: compile={rec['compile_s']}s "
                    f"compute={rec['compute_s']:.3e}s memory={rec['memory_s']:.3e}s "
                    f"collective={rec['collective_s']:.3e}s dominant={rec['dominant']} "
                    f"useful={rec['useful_flops_ratio']:.2f}",
                    flush=True,
                )
                print(f"  memory_analysis: {rec['memory']}", flush=True)
            except Exception as e:
                rec = {
                    "arch": arch_name,
                    "shape": shape,
                    "mesh": mesh_name,
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                print(f"  FAILED: {rec['error']}", flush=True)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
