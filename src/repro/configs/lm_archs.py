"""The five assigned LM architecture configs (exact public-literature specs).

    internlm2-20b        [arXiv:2403.17297; hf]   48L d=6144 48H kv8 ff=16384 V=92544
    phi4-mini-3.8b       [arXiv:2412.08905; hf]   32L d=3072 24H kv8 ff=8192  V=200064
    minitron-4b          [arXiv:2407.14679; hf]   32L d=3072 24H kv8 ff=9216  V=256000
    kimi-k2-1t-a32b      [arXiv:2501.kimi2]       61L d=7168 64H kv8 ff=2048  V=163840  MoE 384e top-8
    granite-moe-1b-a400m [hf:ibm-granite/...]     24L d=1024 16H kv8 ff=512   V=49155   MoE 32e top-8
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import Arch, register
from repro.configs.lm_common import LMArchParams, lm_cells, lm_smoke
from repro.models.transformer import TransformerConfig

INTERNLM2_20B = TransformerConfig(
    name="internlm2-20b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    rope_theta=1_000_000.0,
    param_dtype=jnp.bfloat16,
    tie_embeddings=False,
)

PHI4_MINI = TransformerConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    rope_theta=10_000.0,
    param_dtype=jnp.bfloat16,
    tie_embeddings=True,
)

MINITRON_4B = TransformerConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    rope_theta=10_000.0,
    param_dtype=jnp.bfloat16,
    tie_embeddings=True,
)

KIMI_K2 = TransformerConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=2048,  # per-expert hidden
    vocab=163840,
    rope_theta=50_000.0,
    n_experts=384,
    moe_top_k=8,
    n_shared_experts=1,
    capacity_factor=1.0,
    param_dtype=jnp.bfloat16,
    tie_embeddings=True,
)

GRANITE_MOE = TransformerConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,  # per-expert hidden
    vocab=49155,
    rope_theta=10_000.0,
    n_experts=32,
    moe_top_k=8,
    capacity_factor=1.25,
    param_dtype=jnp.bfloat16,
    tie_embeddings=True,
)


def _lm_arch(name: str, cfg: TransformerConfig, moment_dtype: str = "float32", notes: str = "",
             fsdp_params: bool = False) -> Arch:
    ap = LMArchParams(cfg=cfg, moment_dtype=moment_dtype, fsdp_params=fsdp_params)
    return Arch(
        name=name,
        family="lm",
        cells=lambda: lm_cells(name, ap),
        smoke=lambda: lm_smoke(cfg),
        notes=notes,
    )


@register("internlm2-20b")
def _internlm2():
    return _lm_arch("internlm2-20b", INTERNLM2_20B, notes="dense GQA; CA-RAG generator backbone")


@register("phi4-mini-3.8b")
def _phi4():
    return _lm_arch("phi4-mini-3.8b", PHI4_MINI, notes="dense RoPE SwiGLU GQA; cheap generator tier")


@register("minitron-4b")
def _minitron():
    return _lm_arch("minitron-4b", MINITRON_4B, notes="pruned nemotron; cheap generator tier")


@register("kimi-k2-1t-a32b")
def _kimi():
    return _lm_arch(
        "kimi-k2-1t-a32b",
        KIMI_K2,
        moment_dtype="int8",  # 1T params: quantized Adam moments fit 16GB/chip
        fsdp_params=True,  # ZeRO-3: bf16 params sharded over data axes too
        notes="trillion-param MoE; premium generator tier; EP over model axis",
    )


@register("granite-moe-1b-a400m")
def _granite():
    return _lm_arch("granite-moe-1b-a400m", GRANITE_MOE, notes="32e top-8 MoE; embedder/generator tier")


# Name → TransformerConfig map for launch/train.py
REGISTRY_CONFIGS = {
    "internlm2-20b": INTERNLM2_20B,
    "phi4-mini-3.8b": PHI4_MINI,
    "minitron-4b": MINITRON_4B,
    "kimi-k2-1t-a32b": KIMI_K2,
    "granite-moe-1b-a400m": GRANITE_MOE,
}
