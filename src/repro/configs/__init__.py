"""Architecture configs: the 10 assigned archs + the paper's own CA-RAG config.

Importing this package registers every arch in base.REGISTRY.
"""
import repro.configs.gnn_arch  # noqa: F401
import repro.configs.lm_archs  # noqa: F401
import repro.configs.recsys_archs  # noqa: F401
from repro.configs.base import REGISTRY, all_arch_names, get_arch
