"""Shared cell factory for the five assigned LM architectures.

Shapes (assignment):
    train_4k     seq 4,096   × global_batch 256   → train_step
    prefill_32k  seq 32,768  × global_batch 32    → serve_step (prefill)
    decode_32k   KV 32,768   × global_batch 128   → serve_step (decode)
    long_500k    KV 524,288  × global_batch 1     → serve_step (decode)

long_500k note (DESIGN.md §5): these are full-attention (GQA) models, so the
long-context cell is *decode-only* — one token against a sequence-sharded
524k KV cache is O(S) per step and fits HBM under SP; 500k *prefill* would
be O(S²) and is intentionally not offered.

Train cells run the full production step: loss (remat'd scan) → grads →
AdamW update (int8 moments for the 1T-param kimi config so optimizer state
fits 16 GB/chip).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    BuiltCell,
    CellSpec,
    ScanCorrection,
    policy_for_mesh,
    sanitize_spec,
    shard,
    shard_tree_like,
)
from repro.distributed.partition import ShardingPolicy, spec_for_path, zero_shard
from repro.models.kvcache import KVCache
from repro.models.transformer import (
    TransformerConfig,
    active_param_count,
    decode_step,
    init_params,
    loss_fn,
    param_count,
    prefill,
)
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

TRAIN_SHAPE = dict(seq_len=4096, global_batch=256)
PREFILL_SHAPE = dict(seq_len=32768, global_batch=32)
DECODE_SHAPE = dict(seq_len=32768, global_batch=128)
LONG_SHAPE = dict(seq_len=524288, global_batch=1)


def _param_spec_fn(
    policy: ShardingPolicy, axis_sizes: dict, *, zero_data: bool = False, fsdp_params: bool = False
):
    """path → PartitionSpec for params and optimizer-moment trees.

    ``zero_data``: ZeRO-shard optimizer moments over the data axes.
    ``fsdp_params``: additionally data-shard the *params* (FSDP/ZeRO-3) —
    required for the 1T-param config (bf16 params alone are 125 GB/chip
    under TP-only sharding); XLA inserts the per-use all-gathers.
    Quantized moments are (q: param-shaped, scale: blockwise last-axis,
    same rank) under numeric tuple keys — both take the param's spec
    (sanitize_spec drops non-divisible dims like the scale's small tail).
    """

    def fn(path: str, leaf) -> P:
        # Quantized moments flatten as <param-path>/q and /scale — strip the
        # field names so the PARAM name resolves the spec.
        parts = [p for p in path.split("/") if p and p not in ("q", "scale")]
        if parts and parts[0] in ("m", "v", "mom"):
            named = [p for p in parts[1:] if not p.isdigit()]
            if not named:
                return P()
            base = spec_for_path(named[-1], policy)
            if zero_data:
                base = zero_shard(base, leaf.shape, policy.data_axes, axis_sizes)
            return base
        if parts and parts[0] == "step":
            return P()
        named = [p for p in parts if not p.isdigit()]
        base = spec_for_path(named[-1] if named else "", policy)
        if fsdp_params:
            base = zero_shard(base, leaf.shape, policy.data_axes, axis_sizes)
        return base

    return fn


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _tokens_spec(policy, batch, seq):
    return P(policy.dp, None) if batch > 1 else P(None, None)


@dataclasses.dataclass(frozen=True)
class LMArchParams:
    cfg: TransformerConfig
    moment_dtype: str = "float32"  # "int8" for the 1T MoE
    fsdp_params: bool = False  # ZeRO-3 param sharding (1T MoE)

    def flops_per_token_fwd(self) -> float:
        return 2.0 * active_param_count(self.cfg)


# --------------------------------------------------------------------------- #
# Scan-body correction pieces (see base.ScanCorrection)                        #
# --------------------------------------------------------------------------- #
def _single_layer_abstract(cfg: TransformerConfig):
    """ShapeDtypeStructs for ONE layer's params (no leading L)."""
    full = _abstract(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda leaf: jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype), full["layers"]
    )


def _body_spec_fn(policy: ShardingPolicy):
    """Param path → spec for single-layer (un-stacked) params."""
    from jax.sharding import PartitionSpec as P

    table = {
        "wq": P(None, policy.tp),
        "wk": P(None, policy.tp),
        "wv": P(None, policy.tp),
        "wo": P(policy.tp, None),
        "w_gate": P(None, policy.tp),
        "w_up": P(None, policy.tp),
        "w_down": P(policy.tp, None),
        "router": P(),
        "e_gate": P(policy.tp, None, None),
        "e_up": P(policy.tp, None, None),
        "e_down": P(policy.tp, None, None),
        "s_gate": P(None, policy.tp),
        "s_up": P(None, policy.tp),
        "s_down": P(policy.tp, None),
    }

    def fn(path, leaf):
        name = [p for p in path.split("/") if p and not p.isdigit()]
        return table.get(name[-1] if name else "", P())

    return fn


def _layer_forward(cfg: TransformerConfig, policy, positions):
    """One decoder layer as a standalone function (mirrors the scan body)."""
    from repro.models import layers as L
    from repro.models.transformer import _attention_block, _ffn_block

    inv_freq = L.rope_frequencies(cfg.head_dim, cfg.rope_theta)

    def body(lp, x):
        h = L.rmsnorm({"scale": lp["ln1_scale"]}, x)
        attn, _ = _attention_block(lp, cfg, h, positions, inv_freq, q_block=cfg.q_block)
        x = x + attn
        h2 = L.rmsnorm({"scale": lp["ln2_scale"]}, x)
        ffn, _ = _ffn_block(lp, cfg, h2, policy)  # keep EP dispatch sharding
        return x + ffn

    return body


def _lm_scan_corrections(cfg, mesh, policy, B, S, mode: str) -> list:
    """Build ScanCorrection entries for an LM cell.

    train (remat="full"): raw scan counts (2·fwd + bwd) once → add
        (L−1)·(fwd + fwd+bwd) = (L−1)·(cost(fwd_body) + cost(grad_body)).
    prefill: add (L−1)·cost(fwd_body).
    decode: add (L−1)·cost(decode_body).
    """
    L_layers = cfg.n_layers
    if L_layers <= 1:
        return []
    lp_s = _single_layer_abstract(cfg)
    lp_sh = shard_tree_like(lp_s, mesh, _body_spec_fn(policy))
    x_s = jax.ShapeDtypeStruct((B, max(S, 1) if mode != "decode" else 1, cfg.d_model), cfg.compute_dtype)
    x_sh = shard(mesh, policy.dp if B > 1 else None, None, None)
    out = []
    if mode in ("train", "prefill"):
        positions = jnp.arange(S, dtype=jnp.int32)
        fwd = _layer_forward(cfg, policy, positions)
        out.append(ScanCorrection(fwd, (lp_s, x_s), (lp_sh, x_sh), float(L_layers - 1)))
        if mode == "train":
            def grad_body(lp, x):
                loss = lambda lp, x: jnp.sum(fwd(lp, x).astype(jnp.float32))
                return jax.grad(loss, argnums=(0, 1))(lp, x)

            out.append(ScanCorrection(grad_body, (lp_s, x_s), (lp_sh, x_sh), float(L_layers - 1)))
    elif mode == "decode_q8":
        from repro.kernels.decode_attention.kernel import quantize_kv
        from repro.models import layers as Lm

        inv_freq = Lm.rope_frequencies(cfg.head_dim, cfg.rope_theta)
        dh = cfg.head_dim
        cd = cfg.compute_dtype

        def decode_q8_body(lp, x, kq, ks, vq, vs, positions):
            b = x.shape[0]
            h = Lm.rmsnorm({"scale": lp["ln1_scale"]}, x)
            q = (h @ lp["wq"].astype(cd)).reshape(b, 1, cfg.n_heads, dh)
            k1 = (h @ lp["wk"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
            v1 = (h @ lp["wv"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
            q = Lm.apply_rope(q, positions[:, None], inv_freq)
            k1 = Lm.apply_rope(k1, positions[:, None], inv_freq)
            k1q, k1s = quantize_kv(k1)
            v1q, v1s = quantize_kv(v1)
            bi = jnp.arange(b)
            kq = kq.at[bi, positions].set(k1q[:, 0])
            ks = ks.at[bi, positions].set(k1s[:, 0])
            vq = vq.at[bi, positions].set(v1q[:, 0])
            vs = vs.at[bi, positions].set(v1s[:, 0])
            k_deq = kq.astype(cd) * ks[..., None].astype(cd)
            v_deq = vq.astype(cd) * vs[..., None].astype(cd)
            attn = Lm.gqa_attention(q, k_deq, v_deq, causal=False,
                                    kv_length=positions + 1).reshape(b, 1, cfg.n_heads * dh)
            x = x + attn @ lp["wo"].astype(cd)
            h2 = Lm.rmsnorm({"scale": lp["ln2_scale"]}, x)
            from repro.models.transformer import _ffn_block

            ffn, _ = _ffn_block(lp, cfg, h2, policy)
            return x + ffn, kq, ks, vq, vs

        kvq_s = jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, dh), jnp.int8)
        sc_s = jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads), jnp.float32)
        if B == 1:
            kv_sh = shard(mesh, None, tuple(mesh.axis_names), None, None)
            sc_sh = shard(mesh, None, tuple(mesh.axis_names), None)
            pos_sh = shard(mesh, None)
        else:
            kv_sh = shard(mesh, policy.dp, policy.tp, None, None)
            sc_sh = shard(mesh, policy.dp, policy.tp, None)
            pos_sh = shard(mesh, policy.dp)
        x1_s = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cd)
        x1_sh = shard(mesh, policy.dp if B > 1 else None, None, None)
        pos_s = jax.ShapeDtypeStruct((B,), jnp.int32)
        out.append(
            ScanCorrection(
                decode_q8_body,
                (lp_s, x1_s, kvq_s, sc_s, kvq_s, sc_s, pos_s),
                (lp_sh, x1_sh, kv_sh, sc_sh, kv_sh, sc_sh, pos_sh),
                float(L_layers - 1),
            )
        )
    else:  # decode
        from repro.models import layers as Lm

        inv_freq = Lm.rope_frequencies(cfg.head_dim, cfg.rope_theta)
        dh = cfg.head_dim
        cd = cfg.compute_dtype

        def decode_body(lp, x, k_cache, v_cache, positions):
            b = x.shape[0]
            h = Lm.rmsnorm({"scale": lp["ln1_scale"]}, x)
            q = (h @ lp["wq"].astype(cd)).reshape(b, 1, cfg.n_heads, dh)
            k1 = (h @ lp["wk"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
            v1 = (h @ lp["wv"].astype(cd)).reshape(b, 1, cfg.n_kv_heads, dh)
            q = Lm.apply_rope(q, positions[:, None], inv_freq)
            k1 = Lm.apply_rope(k1, positions[:, None], inv_freq)
            bi = jnp.arange(b)
            k_cache = k_cache.at[bi, positions].set(k1[:, 0].astype(k_cache.dtype))
            v_cache = v_cache.at[bi, positions].set(v1[:, 0].astype(v_cache.dtype))
            attn = Lm.gqa_attention(q, k_cache.astype(cd), v_cache.astype(cd), causal=False,
                                    kv_length=positions + 1).reshape(b, 1, cfg.n_heads * dh)
            x = x + attn @ lp["wo"].astype(cd)
            h2 = Lm.rmsnorm({"scale": lp["ln2_scale"]}, x)
            from repro.models.transformer import _ffn_block

            ffn, _ = _ffn_block(lp, cfg, h2, policy)
            return x + ffn, k_cache, v_cache

        kv_s = jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, dh), jnp.bfloat16)
        if B == 1:
            kv_sh = shard(mesh, None, tuple(mesh.axis_names), None, None)
            pos_sh = shard(mesh, None)
        else:
            kv_sh = shard(mesh, policy.dp, policy.tp, None, None)
            pos_sh = shard(mesh, policy.dp)
        x1_s = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cd)
        x1_sh = shard(mesh, policy.dp if B > 1 else None, None, None)
        pos_s = jax.ShapeDtypeStruct((B,), jnp.int32)
        out.append(
            ScanCorrection(
                decode_body,
                (lp_s, x1_s, kv_s, kv_s, pos_s),
                (lp_sh, x1_sh, kv_sh, kv_sh, pos_sh),
                float(L_layers - 1),
            )
        )
    return out


def make_train_cell(arch: str, ap: LMArchParams) -> CellSpec:
    base_cfg = dataclasses.replace(ap.cfg, remat="full", q_block=512)
    opt_cfg = AdamWConfig(lr=3e-4, weight_decay=0.1, moment_dtype=ap.moment_dtype)
    B, S = TRAIN_SHAPE["global_batch"], TRAIN_SHAPE["seq_len"]

    def build(mesh, policy) -> BuiltCell:
        axis_sizes = dict(mesh.shape)
        # per-data-shard MoE dispatch groups (§Perf iteration 2)
        dp_world = 1
        for a in policy.data_axes:
            dp_world *= axis_sizes[a]
        cfg = (
            dataclasses.replace(base_cfg, moe_groups=dp_world)
            if base_cfg.is_moe
            else base_cfg
        )

        def step(params, opt_state, tokens, targets):
            def lf(p):
                return loss_fn(p, cfg, tokens, targets, policy=policy, loss_chunk=512)

            (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
            new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg)
            return new_params, new_opt, {"loss": loss, **aux, **om}

        params_s = _abstract(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        opt_s = _abstract(lambda p: adamw_init(p, opt_cfg), params_s)
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        spec_fn = _param_spec_fn(policy, axis_sizes, zero_data=True, fsdp_params=ap.fsdp_params)
        in_shardings = (
            shard_tree_like(params_s, mesh, spec_fn),
            shard_tree_like(opt_s, mesh, spec_fn),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp, None),
        )
        n_active = active_param_count(cfg)
        model_flops = 6.0 * n_active * B * S
        metrics_sh = {
            k: shard(mesh)
            for k in ("loss", "lm_loss", "aux_loss", "z_loss", "grad_norm", "lr")
        }
        return BuiltCell(
            fn=step,
            input_specs=(params_s, opt_s, tokens, tokens),
            in_shardings=in_shardings,
            model_flops_per_step=model_flops,
            description=f"{arch} train_4k: B={B} S={S} params={param_count(cfg):,} active={n_active:,}",
            scan_corrections=_lm_scan_corrections(cfg, mesh, policy, B, S, "train"),
            out_shardings=(in_shardings[0], in_shardings[1], metrics_sh),
        )

    return CellSpec(arch, "train_4k", "train", build)


def make_prefill_cell(arch: str, ap: LMArchParams) -> CellSpec:
    cfg = dataclasses.replace(ap.cfg, q_block=512, max_seq_len=PREFILL_SHAPE["seq_len"])
    B, S = PREFILL_SHAPE["global_batch"], PREFILL_SHAPE["seq_len"]

    def build(mesh, policy) -> BuiltCell:
        def step(params, tokens):
            return prefill(params, cfg, tokens, max_len=S, policy=policy)

        params_s = _abstract(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        spec_fn = _param_spec_fn(policy, dict(mesh.shape))
        in_shardings = (
            shard_tree_like(params_s, mesh, spec_fn),
            shard(mesh, policy.dp, None),
        )
        n_active = active_param_count(cfg)
        dh = cfg.head_dim
        attn_flops = cfg.n_layers * 2.0 * B * cfg.n_heads * S * S * dh  # QKᵀ+PV, causal ≈ ×0.5×2
        model_flops = 2.0 * n_active * B * S + attn_flops
        return BuiltCell(
            fn=step,
            input_specs=(params_s, tokens),
            in_shardings=in_shardings,
            model_flops_per_step=model_flops,
            description=f"{arch} prefill_32k: B={B} S={S}",
            scan_corrections=_lm_scan_corrections(cfg, mesh, policy, B, S, "prefill"),
        )

    return CellSpec(arch, "prefill_32k", "prefill", build)


def make_decode_cell(arch: str, ap: LMArchParams, shape_name: str) -> CellSpec:
    import os as _os

    sh = DECODE_SHAPE if shape_name == "decode_32k" else LONG_SHAPE
    B, S = sh["global_batch"], sh["seq_len"]
    cfg = dataclasses.replace(ap.cfg, max_seq_len=S)
    kv_int8 = _os.environ.get("REPRO_KV_DTYPE", "bf16") == "int8"

    def build(mesh, policy) -> BuiltCell:
        # long-context: shard the KV sequence over EVERY mesh axis (batch=1
        # leaves dp idle otherwise); decode_32k: batch over dp, seq over model.
        if B == 1:
            all_axes = tuple(mesh.axis_names)
            kv_spec = P(None, None, all_axes, None, None)
            batch_spec = P(None)
        else:
            kv_spec = P(None, policy.dp, policy.tp, None, None)
            batch_spec = P(policy.dp)

        params_s = _abstract(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        dh = cfg.head_dim
        lengths = jax.ShapeDtypeStruct((B,), jnp.int32)
        tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
        spec_fn = _param_spec_fn(policy, dict(mesh.shape))
        scale_spec = jax.sharding.NamedSharding(
            mesh, type(kv_spec)(*[e for e in kv_spec][:-1])
        )
        if kv_int8:
            from repro.models.transformer import decode_step_q8

            def step(params, kq, ks, vq, vs, lengths, tokens):
                logits, kq2, ks2, vq2, vs2, len2 = decode_step_q8(
                    params, cfg, kq, ks, vq, vs, lengths, tokens, policy=None
                )
                kq2 = jax.lax.with_sharding_constraint(kq2, kv_spec)
                vq2 = jax.lax.with_sharding_constraint(vq2, kv_spec)
                return logits, kq2, ks2, vq2, vs2, len2

            kv = jax.ShapeDtypeStruct((cfg.n_layers, B, S, cfg.n_kv_heads, dh), jnp.int8)
            kv_scale = jax.ShapeDtypeStruct((cfg.n_layers, B, S, cfg.n_kv_heads), jnp.float32)
            inputs = (params_s, kv, kv_scale, kv, kv_scale, lengths, tokens)
            in_shardings = (
                shard_tree_like(params_s, mesh, spec_fn),
                jax.sharding.NamedSharding(mesh, kv_spec),
                scale_spec,
                jax.sharding.NamedSharding(mesh, kv_spec),
                scale_spec,
                shard(mesh, *batch_spec),
                shard(mesh, *batch_spec),
            )
        else:
            def step(params, k, v, lengths, tokens):
                cache = KVCache(k=k, v=v, lengths=lengths)
                logits, new_cache = decode_step(params, cfg, cache, tokens, policy=None)
                k2 = jax.lax.with_sharding_constraint(new_cache.k, kv_spec)
                v2 = jax.lax.with_sharding_constraint(new_cache.v, kv_spec)
                return logits, k2, v2, new_cache.lengths

            kv = jax.ShapeDtypeStruct((cfg.n_layers, B, S, cfg.n_kv_heads, dh), jnp.bfloat16)
            inputs = (params_s, kv, kv, lengths, tokens)
            in_shardings = (
                shard_tree_like(params_s, mesh, spec_fn),
                jax.sharding.NamedSharding(mesh, kv_spec),
                jax.sharding.NamedSharding(mesh, kv_spec),
                shard(mesh, *batch_spec),
                shard(mesh, *batch_spec),
            )
        n_active = active_param_count(cfg)
        attn_flops = cfg.n_layers * 4.0 * B * cfg.n_heads * S * dh
        model_flops = 2.0 * n_active * B + attn_flops
        return BuiltCell(
            fn=step,
            input_specs=inputs,
            in_shardings=in_shardings,
            model_flops_per_step=model_flops,
            description=f"{arch} {shape_name}: B={B} KV={S} kv_dtype={'int8' if kv_int8 else 'bf16'}",
            scan_corrections=_lm_scan_corrections(
                cfg, mesh, policy, B, S, "decode_q8" if kv_int8 else "decode"
            ),
        )

    return CellSpec(arch, shape_name, "decode", build)


def lm_cells(arch: str, ap: LMArchParams) -> dict[str, CellSpec]:
    return {
        "train_4k": make_train_cell(arch, ap),
        "prefill_32k": make_prefill_cell(arch, ap),
        "decode_32k": make_decode_cell(arch, ap, "decode_32k"),
        "long_500k": make_decode_cell(arch, ap, "long_500k"),
    }


def lm_smoke(cfg_full: TransformerConfig, **reduce_kw) -> dict:
    """Reduced-config smoke: one forward + train step + decode on CPU."""
    reduced = dataclasses.replace(
        cfg_full,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg_full.n_kv_heads, 2),
        d_head=16,
        d_ff=128,
        vocab=211,
        n_experts=(4 if cfg_full.is_moe else None),
        moe_top_k=(2 if cfg_full.is_moe else 0),
        n_shared_experts=min(cfg_full.n_shared_experts, 1),
        compute_dtype=jnp.float32,
        param_dtype=jnp.float32,
        max_seq_len=32,
        remat="none",
        q_block=None,
        **reduce_kw,
    )
    params = init_params(jax.random.PRNGKey(0), reduced)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, reduced.vocab)
    loss, metrics = loss_fn(params, reduced, toks, toks)
    logits, cache = prefill(params, reduced, toks, max_len=32)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    d_logits, cache = decode_step(params, reduced, cache, nxt)
    assert logits.shape == (2, reduced.vocab)
    assert d_logits.shape == (2, reduced.vocab)
    finite = bool(
        np.isfinite(float(loss))
        and np.isfinite(np.asarray(logits)).all()
        and np.isfinite(np.asarray(d_logits)).all()
    )
    return {"loss": float(loss), "finite": finite, "logits_shape": tuple(logits.shape)}
