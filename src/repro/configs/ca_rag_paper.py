"""The paper's own experiment configuration, in one place.

Everything the 28-query benchmark run uses — Table I catalog, §V.A
complexity constants, Eq. 1 weights, the calibrated modulation constants,
telemetry/refinement settings, and the latency-model constants — so the
reproduction is auditable from a single module (see EXPERIMENTS.md
§Calibration for how the free parameters were fit and which paper numbers
pinned them).
"""

from __future__ import annotations

import dataclasses

from repro.core.bundles import DEFAULT_CATALOG, BundleCatalog
from repro.core.router import RouterConfig
from repro.core.signals import DEFAULT_ALPHA, DEFAULT_BETA, DEFAULT_K_MAX, DEFAULT_L_MAX
from repro.core.utility import (
    COST_SENSITIVE_WEIGHTS,
    DEFAULT_C0,
    DEFAULT_C1,
    DEFAULT_DELTA,
    DEFAULT_GAMMA,
    DEFAULT_GLOBAL_DECAY,
    DEFAULT_WEIGHTS,
    LATENCY_SENSITIVE_WEIGHTS,
)
from repro.serving.engine import EngineConfig
from repro.serving.latency import LatencyModelConfig


@dataclasses.dataclass(frozen=True)
class CARAGPaperConfig:
    """Paper-pinned values (Table I, §V.A, §V.C) + calibrated free params."""

    catalog: BundleCatalog = DEFAULT_CATALOG
    # §V.A — paper-specified exactly
    alpha: float = DEFAULT_ALPHA  # 0.6
    beta: float = DEFAULT_BETA  # 0.4
    l_max: float = DEFAULT_L_MAX  # 20
    k_max: float = DEFAULT_K_MAX  # 3
    # Eq. 1 weights — paper-specified exactly
    weights: tuple = DEFAULT_WEIGHTS.as_tuple()  # (0.6, 0.2, 0.2)
    weights_latency_sensitive: tuple = LATENCY_SENSITIVE_WEIGHTS.as_tuple()
    weights_cost_sensitive: tuple = COST_SENSITIVE_WEIGHTS.as_tuple()
    # quality-prior modulation — form unspecified in the paper; calibrated
    gamma: float = DEFAULT_GAMMA
    c0: float = DEFAULT_C0
    delta: float = DEFAULT_DELTA
    c1: float = DEFAULT_C1
    global_decay: float = DEFAULT_GLOBAL_DECAY

    def router_config(self) -> RouterConfig:
        return RouterConfig()

    def engine_config(self) -> EngineConfig:
        return EngineConfig()

    def latency_config(self) -> LatencyModelConfig:
        return LatencyModelConfig()


PAPER_CONFIG = CARAGPaperConfig()
