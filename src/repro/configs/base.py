"""Config/cell plumbing shared by all architecture configs.

Every architecture module registers an :class:`Arch` with:

* ``cells()`` — the assigned (shape → CellSpec) set. A CellSpec builds, for
  a given mesh+policy, the jit-able step function plus ShapeDtypeStruct
  inputs and their NamedShardings — everything ``launch/dryrun.py`` needs to
  ``.lower().compile()`` without allocating a single real array.
* ``smoke()`` — a REDUCED config of the same family that runs one real
  forward/train step on CPU (tests/test_configs_smoke.py asserts shapes +
  finiteness).

Hardware/roofline constants for the target (TPU v5e) live here too.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.partition import ShardingPolicy

# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass
class ScanCorrection:
    """XLA's cost_analysis counts a while/scan body ONCE regardless of trip
    count (verified experimentally). Each entry compiles a standalone scan
    body and its cost is added ``multiplier`` times to the raw totals:
        corrected = raw + Σ multiplier_i × cost(body_i).
    """

    fn: Callable
    input_specs: tuple
    in_shardings: tuple
    multiplier: float


@dataclasses.dataclass
class BuiltCell:
    """Everything dryrun.py needs for one (arch × shape × mesh) lowering."""

    fn: Callable  # positional-args step function
    input_specs: tuple  # pytree of jax.ShapeDtypeStruct, positional
    in_shardings: tuple  # matching pytree of NamedSharding
    model_flops_per_step: float  # 6·N·D style analytic FLOPs (fwd+bwd if train)
    description: str = ""
    scan_corrections: list = dataclasses.field(default_factory=list)
    out_shardings: object = None  # optional pytree matching fn's outputs


@dataclasses.dataclass(frozen=True)
class CellSpec:
    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve | retrieval
    build: Callable[[jax.sharding.Mesh, ShardingPolicy], BuiltCell]


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    family: str  # lm | gnn | recsys
    cells: Callable[[], dict[str, CellSpec]]
    smoke: Callable[[], dict]  # runs reduced config; returns metrics
    notes: str = ""


def policy_for_mesh(mesh: jax.sharding.Mesh, **kwargs) -> ShardingPolicy:
    axes = tuple(mesh.axis_names)
    if "pod" in axes:
        return ShardingPolicy(data_axes=("pod", "data"), model_axis="model", **kwargs)
    if "model" in axes:
        return ShardingPolicy(data_axes=("data",), model_axis="model", **kwargs)
    return ShardingPolicy(data_axes=(axes[0],), model_axis=None, **kwargs)


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Drop sharding on dims whose size isn't divisible by the axis product.

    pjit ``in_shardings`` demands exact divisibility (unlike
    with_sharding_constraint); odd dims (e.g. granite's 49,155-row vocab)
    replicate instead.
    """
    sizes = dict(mesh.shape)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries[: len(shape)]):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        world = 1
        for a in axes:
            world *= sizes[a]
        out.append(entry if dim % world == 0 else None)
    return P(*out)


def shard(mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def pad_to_multiple(n: int, multiple: int = 512) -> int:
    """Pad a leading dim so every production mesh (256/512 chips) divides it."""
    return -(-n // multiple) * multiple


def shard_tree_like(tree, mesh, spec_fn):
    """Map a pytree of ShapeDtypeStructs to NamedShardings via path→spec."""
    def to_sharding(path, leaf):
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
        spec = spec_fn("/".join(parts), leaf)
        return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(to_sharding, tree)


def replicated_tree(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


REGISTRY: dict[str, Callable[[], Arch]] = {}


def register(name: str):
    def deco(fn):
        REGISTRY[name] = fn
        return fn

    return deco


def get_arch(name: str) -> Arch:
    if name not in REGISTRY:
        # import side-effect registration
        import repro.configs  # noqa: F401

    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]()


def all_arch_names() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(REGISTRY)
