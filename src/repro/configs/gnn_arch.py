"""gin-tu [arXiv:1810.00826]: 5 layers, hidden 64, sum aggregator, learnable ε.

Cells (assignment):
    full_graph_sm  Cora-scale:     2,708 nodes / 10,556 edges / d=1433   (full-batch train)
    minibatch_lg   Reddit-scale:   232,965 nodes / 114.6M edges, batch 1024, fanout 15-10
                   → static padded subgraph (169,984 nodes / 168,960 edges, d=602)
    ogb_products   2,449,029 nodes / 61,859,140 edges / d=100            (full-batch train)
    molecule       128 graphs × 30 nodes / 64 edges                      (graph classification)

Distribution: node-feature/activation rows shard over (data×model) for the
large full-batch cells (the segment_sum scatter over sharded destinations is
the collective the roofline table surfaces); CA-RAG applicability note in
DESIGN.md §5 — routing composes around the GNN as a corpus-graph retrieval
stage without modifying message passing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    Arch,
    BuiltCell,
    CellSpec,
    pad_to_multiple,
    register,
    replicated_tree,
    shard,
)
from repro.models.gnn import GINConfig, NeighborSampler, graph_loss, init_params, node_loss
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

GIN_TU = GINConfig(name="gin-tu", n_layers=5, d_hidden=64, d_feat=1433, n_classes=7)

SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
    "minibatch_lg": dict(
        graph_nodes=232965, graph_edges=114615892, batch_nodes=1024, fanouts=(15, 10),
        d_feat=602, n_classes=41,
    ),
    # padded to 512-divisible (2,449,029 → 2,449,408 nodes; 61,859,140 →
    # 61,859,328 edges): pad nodes are isolated, pad edges self-loop on a pad
    # node with zero label mask — preprocessing, not model change.
    "ogb_products": dict(n_nodes=pad_to_multiple(2449029), n_edges=pad_to_multiple(61859140), d_feat=100, n_classes=47),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=16, n_classes=2),
}

_OPT = AdamWConfig(lr=1e-3, max_grad_norm=None)


def _gin_flops(n_nodes, d_feat, d_hidden, n_layers, train=True):
    per_layer0 = 2.0 * n_nodes * (d_feat * d_hidden + d_hidden * d_hidden)
    per_layer = 2.0 * n_nodes * (d_hidden * d_hidden * 2)
    fwd = per_layer0 + (n_layers - 1) * per_layer
    return fwd * (3.0 if train else 1.0)


def _node_train_cell(shape_name: str, *, shard_rows: bool) -> CellSpec:
    sh = SHAPES[shape_name]
    if shape_name == "minibatch_lg":
        n_nodes, n_edges = NeighborSampler.subgraph_shape(sh["batch_nodes"], list(sh["fanouts"]))
        d_feat, n_classes = sh["d_feat"], sh["n_classes"]
    else:
        n_nodes, n_edges = sh["n_nodes"], sh["n_edges"]
        d_feat, n_classes = sh["d_feat"], sh["n_classes"]
    cfg = GINConfig(name="gin-tu", n_layers=5, d_hidden=64, d_feat=d_feat, n_classes=n_classes)

    def build(mesh, policy) -> BuiltCell:
        row_axes = tuple(mesh.axis_names)  # nodes over the whole mesh
        x_spec = P(row_axes, None) if shard_rows else P(None, None)
        e_spec = P(row_axes) if shard_rows else P(None)

        def step(params, opt_state, x, edge_src, edge_dst, labels, label_mask):
            def lf(p):
                return node_loss(p, cfg, x, edge_src, edge_dst, labels, label_mask)

            loss, grads = jax.value_and_grad(lf)(params)
            new_params, new_opt, om = adamw_update(grads, opt_state, params, _OPT)
            return new_params, new_opt, {"loss": loss, **om}

        params_s = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(lambda p: adamw_init(p, _OPT), params_s)
        inputs = (
            params_s,
            opt_s,
            jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32),
            jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
            jax.ShapeDtypeStruct((n_nodes,), jnp.float32),
        )
        in_shardings = (
            replicated_tree(params_s, mesh),
            replicated_tree(opt_s, mesh),
            jax.sharding.NamedSharding(mesh, x_spec),
            jax.sharding.NamedSharding(mesh, e_spec),
            jax.sharding.NamedSharding(mesh, e_spec),
            jax.sharding.NamedSharding(mesh, P(row_axes) if shard_rows else P(None)),
            jax.sharding.NamedSharding(mesh, P(row_axes) if shard_rows else P(None)),
        )
        return BuiltCell(
            fn=step,
            input_specs=inputs,
            in_shardings=in_shardings,
            model_flops_per_step=_gin_flops(n_nodes, d_feat, 64, 5),
            description=f"gin-tu {shape_name}: {n_nodes:,} nodes / {n_edges:,} edges (train)",
        )

    return CellSpec("gin-tu", shape_name, "train", build)


def _molecule_cell() -> CellSpec:
    sh = SHAPES["molecule"]
    batch, npg, epg = sh["batch"], sh["n_nodes"], sh["n_edges"]
    n_nodes, n_edges = batch * npg, batch * epg
    cfg = GINConfig(
        name="gin-tu", n_layers=5, d_hidden=64, d_feat=sh["d_feat"],
        n_classes=sh["n_classes"], readout="graph",
    )

    def build(mesh, policy) -> BuiltCell:
        dp = policy.dp

        def step(params, opt_state, x, edge_src, edge_dst, graph_ids, labels):
            def lf(p):
                return graph_loss(p, cfg, x, edge_src, edge_dst, graph_ids, batch, labels)

            loss, grads = jax.value_and_grad(lf)(params)
            new_params, new_opt, om = adamw_update(grads, opt_state, params, _OPT)
            return new_params, new_opt, {"loss": loss, **om}

        params_s = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(lambda p: adamw_init(p, _OPT), params_s)
        inputs = (
            params_s,
            opt_s,
            jax.ShapeDtypeStruct((n_nodes, sh["d_feat"]), jnp.float32),
            jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
        in_shardings = (
            replicated_tree(params_s, mesh),
            replicated_tree(opt_s, mesh),
            shard(mesh, dp, None),  # nodes grouped per graph → batch-aligned
            shard(mesh, dp),
            shard(mesh, dp),
            shard(mesh, dp),
            shard(mesh, dp),
        )
        return BuiltCell(
            fn=step,
            input_specs=inputs,
            in_shardings=in_shardings,
            model_flops_per_step=_gin_flops(n_nodes, sh["d_feat"], 64, 5),
            description=f"gin-tu molecule: {batch} graphs × {npg}n/{epg}e",
        )

    return CellSpec("gin-tu", "molecule", "train", build)


def _gin_cells() -> dict[str, CellSpec]:
    return {
        "full_graph_sm": _node_train_cell("full_graph_sm", shard_rows=False),
        "minibatch_lg": _node_train_cell("minibatch_lg", shard_rows=False),
        "ogb_products": _node_train_cell("ogb_products", shard_rows=True),
        "molecule": _molecule_cell(),
    }


def _gin_smoke() -> dict:
    from repro.models.gnn import random_graph

    cfg = GINConfig(name="gin_smoke", n_layers=2, d_hidden=16, d_feat=12, n_classes=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    indptr, indices = random_graph(64, 256, seed=0)
    sampler = NeighborSampler(indptr, indices, seed=1)
    sub = sampler.sample(np.arange(8), fanouts=[3, 2])
    x = jax.random.normal(jax.random.PRNGKey(1), (len(sub["node_ids"]), 12))
    labels = jnp.zeros((x.shape[0],), jnp.int32)
    mask = jnp.zeros((x.shape[0],)).at[:8].set(1.0)
    loss, grads = jax.value_and_grad(
        lambda p: node_loss(p, cfg, x, jnp.asarray(sub["edge_src"]), jnp.asarray(sub["edge_dst"]), labels, mask)
    )(params)
    finite = np.isfinite(float(loss)) and all(
        np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads)
    )
    return {"loss": float(loss), "finite": bool(finite), "sub_nodes": int(x.shape[0])}


@register("gin-tu")
def _gin() -> Arch:
    return Arch(
        name="gin-tu",
        family="gnn",
        cells=_gin_cells,
        smoke=_gin_smoke,
        notes="segment_sum message passing; real layered neighbor sampler for minibatch_lg",
    )
