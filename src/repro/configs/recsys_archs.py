"""The four assigned recsys architecture configs.

    dlrm-mlperf  [arXiv:1906.00091]  13 dense / 26 sparse / embed 128 / dot interaction
    deepfm       [arXiv:1703.04247]  39 sparse / embed 10 / FM + 400-400-400 MLP
    mind         [arXiv:1904.08030]  embed 64 / 4 interests / 3 capsule iters
    sasrec       [arXiv:1808.09781]  embed 50 / 2 blocks / 1 head / seq 50

Shapes: train_batch 65,536 (train) · serve_p99 512 · serve_bulk 262,144
(forward scoring) · retrieval_cand 1 × 1,000,000 (batched-dot + blocked
top-k — never a loop).

Distribution: the big embedding tables shard row-wise over the whole mesh
(DLRM's 187.7M-row Criteo table ≈ 96 GB f32 → 375 MB/chip at 256 chips);
lookups against row-sharded tables are the all-to-all-style collective the
roofline table surfaces. MLPs replicate and all-reduce over DP.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    Arch,
    BuiltCell,
    CellSpec,
    pad_to_multiple,
    register,
    replicated_tree,
    shard,
)
from repro.models import recsys as R
from repro.retrieval.topk import blocked_topk
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

TRAIN_B = 65536
P99_B = 512
BULK_B = 262144
N_CAND = pad_to_multiple(1_000_000)  # 1,000,448: padded so 512 chips divide rows
TOPK = 100

_OPT = AdamWConfig(lr=1e-3, max_grad_norm=None)

DLRM = R.DLRMConfig()
DEEPFM = R.DeepFMConfig()
MIND = R.MINDConfig()
SASREC = R.SASRecConfig()


def _row_axes(mesh):
    return tuple(mesh.axis_names)


def _table_spec_fn(mesh, policy, table_keys=("table", "first_order", "item_embed")):
    rows = _row_axes(mesh)

    def fn(path, leaf):
        name = [p for p in path.split("/") if p and not p.isdigit()]
        leaf_name = name[-1] if name else ""
        under_opt = name and name[0] in ("m", "v")
        base_name = name[1] if under_opt and len(name) > 1 else leaf_name
        for key in table_keys:
            if key in path.split("/") or base_name == key:
                if len(leaf.shape) >= 1 and leaf.shape[0] > 100_000:
                    return P(rows, *([None] * (len(leaf.shape) - 1)))
        return P()

    return fn


def _shard_params(tree, mesh, policy):
    from repro.configs.base import shard_tree_like

    return shard_tree_like(tree, mesh, _table_spec_fn(mesh, policy))


def _pad_big_tables(tree):
    """Pad >100k-row leading dims to multiples of 512 (mesh-divisible).

    Lookup semantics are unaffected — padding rows sit past every field
    offset and are never gathered; dry-run memory accounting includes them
    (0.0003% of the DLRM table)."""
    import jax as _jax

    def pad(leaf):
        if len(leaf.shape) >= 1 and leaf.shape[0] > 100_000:
            return _jax.ShapeDtypeStruct(
                (pad_to_multiple(leaf.shape[0]), *leaf.shape[1:]), leaf.dtype
            )
        return leaf

    return _jax.tree.map(pad, tree)


# --------------------------------------------------------------------------- #
# Per-arch input makers (ShapeDtypeStructs)                                    #
# --------------------------------------------------------------------------- #
def _dlrm_inputs(b):
    return (
        jax.ShapeDtypeStruct((b, DLRM.n_dense), jnp.float32),
        jax.ShapeDtypeStruct((b, DLRM.n_sparse), jnp.int32),
    )


def _deepfm_inputs(b):
    return (jax.ShapeDtypeStruct((b, DEEPFM.n_sparse), jnp.int32),)


def _mind_inputs(b):
    return (
        jax.ShapeDtypeStruct((b, MIND.hist_len), jnp.int32),
        jax.ShapeDtypeStruct((b, MIND.hist_len), jnp.float32),
    )


def _sasrec_inputs(b):
    return (jax.ShapeDtypeStruct((b, SASREC.seq_len), jnp.int32),)


# --------------------------------------------------------------------------- #
# Cell factories                                                               #
# --------------------------------------------------------------------------- #
def _recsys_cell(arch, shape, kind, make_build):
    return CellSpec(arch, shape, kind, make_build)


def _dlrm_cells() -> dict[str, CellSpec]:
    def train_build(mesh, policy):
        def step(params, opt_state, dense, sparse, labels):
            loss, grads = jax.value_and_grad(
                lambda p: R.dlrm_loss(p, DLRM, dense, sparse, labels)
            )(params)
            new_p, new_o, om = adamw_update(grads, opt_state, params, _OPT)
            return new_p, new_o, {"loss": loss, **om}

        params_s = _pad_big_tables(R.dlrm_abstract(DLRM))
        opt_s = jax.eval_shape(lambda p: adamw_init(p, _OPT), params_s)
        dense, sparse = _dlrm_inputs(TRAIN_B)
        labels = jax.ShapeDtypeStruct((TRAIN_B,), jnp.float32)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            _shard_params(opt_s, mesh, policy),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp),
        )
        flops = _dlrm_flops(TRAIN_B) * 3
        return BuiltCell(step, (params_s, opt_s, dense, sparse, labels), in_sh, flops,
                         f"dlrm train: B={TRAIN_B}, table rows={DLRM.fields.total_rows:,}")

    def serve_build_factory(b):
        def build(mesh, policy):
            def step(params, dense, sparse):
                return R.dlrm_forward(params, DLRM, dense, sparse)

            params_s = _pad_big_tables(R.dlrm_abstract(DLRM))
            dense, sparse = _dlrm_inputs(b)
            in_sh = (
                _shard_params(params_s, mesh, policy),
                shard(mesh, policy.dp, None),
                shard(mesh, policy.dp, None),
            )
            return BuiltCell(step, (params_s, dense, sparse), in_sh, _dlrm_flops(b),
                             f"dlrm serve: B={b}")

        return build

    def retrieval_build(mesh, policy):
        rows = _row_axes(mesh)

        def step(params, dense, candidates):
            user = R.mlp_apply(params["bot"], dense, activation="relu", final_activation=True)
            scores = user @ candidates.T  # (1, N_CAND)
            return blocked_topk(scores, TOPK)

        params_s = _pad_big_tables(R.dlrm_abstract(DLRM))
        dense = jax.ShapeDtypeStruct((1, DLRM.n_dense), jnp.float32)
        cands = jax.ShapeDtypeStruct((N_CAND, DLRM.embed_dim), jnp.float32)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            shard(mesh, None, None),
            jax.sharding.NamedSharding(mesh, P(rows, None)),
        )
        return BuiltCell(step, (params_s, dense, cands), in_sh, 2.0 * N_CAND * DLRM.embed_dim,
                         f"dlrm retrieval: 1×{N_CAND:,} candidates")

    return {
        "train_batch": _recsys_cell("dlrm-mlperf", "train_batch", "train", train_build),
        "serve_p99": _recsys_cell("dlrm-mlperf", "serve_p99", "serve", serve_build_factory(P99_B)),
        "serve_bulk": _recsys_cell("dlrm-mlperf", "serve_bulk", "serve", serve_build_factory(BULK_B)),
        "retrieval_cand": _recsys_cell("dlrm-mlperf", "retrieval_cand", "retrieval", retrieval_build),
    }


def _dlrm_flops(b):
    bot = 2 * b * (13 * 512 + 512 * 256 + 256 * 128)
    top = 2 * b * (479 * 1024 + 1024 * 1024 + 1024 * 512 + 512 * 256 + 256)
    inter = 2 * b * 27 * 27 * 128
    return float(bot + top + inter)


def _deepfm_cells() -> dict[str, CellSpec]:
    def train_build(mesh, policy):
        def step(params, opt_state, sparse, labels):
            loss, grads = jax.value_and_grad(
                lambda p: R.deepfm_loss(p, DEEPFM, sparse, labels)
            )(params)
            new_p, new_o, om = adamw_update(grads, opt_state, params, _OPT)
            return new_p, new_o, {"loss": loss, **om}

        params_s = _pad_big_tables(jax.eval_shape(lambda k: R.deepfm_init(k, DEEPFM), jax.random.PRNGKey(0)))
        opt_s = jax.eval_shape(lambda p: adamw_init(p, _OPT), params_s)
        (sparse,) = _deepfm_inputs(TRAIN_B)
        labels = jax.ShapeDtypeStruct((TRAIN_B,), jnp.float32)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            _shard_params(opt_s, mesh, policy),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp),
        )
        return BuiltCell(step, (params_s, opt_s, sparse, labels), in_sh, _deepfm_flops(TRAIN_B) * 3,
                         f"deepfm train: B={TRAIN_B}")

    def serve_build_factory(b):
        def build(mesh, policy):
            def step(params, sparse):
                return R.deepfm_forward(params, DEEPFM, sparse)

            params_s = _pad_big_tables(jax.eval_shape(lambda k: R.deepfm_init(k, DEEPFM), jax.random.PRNGKey(0)))
            (sparse,) = _deepfm_inputs(b)
            in_sh = (_shard_params(params_s, mesh, policy), shard(mesh, policy.dp, None))
            return BuiltCell(step, (params_s, sparse), in_sh, _deepfm_flops(b), f"deepfm serve: B={b}")

        return build

    def retrieval_build(mesh, policy):
        rows = _row_axes(mesh)

        def step(params, sparse, candidates):
            emb = R.field_lookup(params["table"], DEEPFM.fields, sparse)  # (1, F, D)
            user = emb.sum(axis=1)  # (1, D)
            scores = user @ candidates.T
            return blocked_topk(scores, TOPK)

        params_s = _pad_big_tables(jax.eval_shape(lambda k: R.deepfm_init(k, DEEPFM), jax.random.PRNGKey(0)))
        (sparse,) = _deepfm_inputs(1)
        cands = jax.ShapeDtypeStruct((N_CAND, DEEPFM.embed_dim), jnp.float32)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            shard(mesh, None, None),
            jax.sharding.NamedSharding(mesh, P(rows, None)),
        )
        return BuiltCell(step, (params_s, sparse, cands), in_sh, 2.0 * N_CAND * DEEPFM.embed_dim,
                         f"deepfm retrieval: 1×{N_CAND:,}")

    return {
        "train_batch": _recsys_cell("deepfm", "train_batch", "train", train_build),
        "serve_p99": _recsys_cell("deepfm", "serve_p99", "serve", serve_build_factory(P99_B)),
        "serve_bulk": _recsys_cell("deepfm", "serve_bulk", "serve", serve_build_factory(BULK_B)),
        "retrieval_cand": _recsys_cell("deepfm", "retrieval_cand", "retrieval", retrieval_build),
    }


def _deepfm_flops(b):
    deep = 2 * b * (390 * 400 + 400 * 400 + 400 * 400 + 400)
    fm = 2 * b * 39 * 10
    return float(deep + fm)


def _mind_cells() -> dict[str, CellSpec]:
    def train_build(mesh, policy):
        def step(params, opt_state, hist, mask, target, negs):
            loss, grads = jax.value_and_grad(
                lambda p: R.mind_loss(p, MIND, hist, mask, target, negs)
            )(params)
            new_p, new_o, om = adamw_update(grads, opt_state, params, _OPT)
            return new_p, new_o, {"loss": loss, **om}

        params_s = _pad_big_tables(jax.eval_shape(lambda k: R.mind_init(k, MIND), jax.random.PRNGKey(0)))
        opt_s = jax.eval_shape(lambda p: adamw_init(p, _OPT), params_s)
        hist, mask = _mind_inputs(TRAIN_B)
        target = jax.ShapeDtypeStruct((TRAIN_B,), jnp.int32)
        negs = jax.ShapeDtypeStruct((MIND.n_negatives,), jnp.int32)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            _shard_params(opt_s, mesh, policy),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp),
            shard(mesh, None),
        )
        return BuiltCell(step, (params_s, opt_s, hist, mask, target, negs), in_sh,
                         _mind_flops(TRAIN_B) * 3, f"mind train: B={TRAIN_B}")

    def serve_build_factory(b):
        def build(mesh, policy):
            def step(params, hist, mask):
                return R.mind_interests(params, MIND, hist, mask)

            params_s = _pad_big_tables(jax.eval_shape(lambda k: R.mind_init(k, MIND), jax.random.PRNGKey(0)))
            hist, mask = _mind_inputs(b)
            in_sh = (
                _shard_params(params_s, mesh, policy),
                shard(mesh, policy.dp, None),
                shard(mesh, policy.dp, None),
            )
            return BuiltCell(step, (params_s, hist, mask), in_sh, _mind_flops(b), f"mind serve: B={b}")

        return build

    def retrieval_build(mesh, policy):
        rows = _row_axes(mesh)

        def step(params, hist, mask, candidates):
            return R.mind_retrieval_score(params, MIND, hist, mask, candidates, TOPK)

        params_s = _pad_big_tables(jax.eval_shape(lambda k: R.mind_init(k, MIND), jax.random.PRNGKey(0)))
        hist, mask = _mind_inputs(1)
        cands = jax.ShapeDtypeStruct((N_CAND, MIND.embed_dim), jnp.float32)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            shard(mesh, None, None),
            shard(mesh, None, None),
            jax.sharding.NamedSharding(mesh, P(rows, None)),
        )
        return BuiltCell(step, (params_s, hist, mask, cands), in_sh,
                         2.0 * MIND.n_interests * N_CAND * MIND.embed_dim,
                         f"mind retrieval: 1×{N_CAND:,}")

    return {
        "train_batch": _recsys_cell("mind", "train_batch", "train", train_build),
        "serve_p99": _recsys_cell("mind", "serve_p99", "serve", serve_build_factory(P99_B)),
        "serve_bulk": _recsys_cell("mind", "serve_bulk", "serve", serve_build_factory(BULK_B)),
        "retrieval_cand": _recsys_cell("mind", "retrieval_cand", "retrieval", retrieval_build),
    }


def _mind_flops(b):
    routing = 2 * b * MIND.capsule_iters * MIND.n_interests * MIND.hist_len * MIND.embed_dim
    bilinear = 2 * b * MIND.hist_len * MIND.embed_dim * MIND.embed_dim
    return float(routing + bilinear)


def _sasrec_cells() -> dict[str, CellSpec]:
    def train_build(mesh, policy):
        def step(params, opt_state, seq, pos, neg):
            loss, grads = jax.value_and_grad(
                lambda p: R.sasrec_loss(p, SASREC, seq, pos, neg)
            )(params)
            new_p, new_o, om = adamw_update(grads, opt_state, params, _OPT)
            return new_p, new_o, {"loss": loss, **om}

        params_s = jax.eval_shape(lambda k: R.sasrec_init(k, SASREC), jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(lambda p: adamw_init(p, _OPT), params_s)
        (seq,) = _sasrec_inputs(TRAIN_B)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            _shard_params(opt_s, mesh, policy),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp, None),
            shard(mesh, policy.dp, None),
        )
        return BuiltCell(step, (params_s, opt_s, seq, seq, seq), in_sh,
                         _sasrec_flops(TRAIN_B) * 3, f"sasrec train: B={TRAIN_B}")

    def serve_build_factory(b):
        def build(mesh, policy):
            def step(params, seq):
                return R.sasrec_hidden(params, SASREC, seq)

            params_s = jax.eval_shape(lambda k: R.sasrec_init(k, SASREC), jax.random.PRNGKey(0))
            (seq,) = _sasrec_inputs(b)
            in_sh = (_shard_params(params_s, mesh, policy), shard(mesh, policy.dp, None))
            return BuiltCell(step, (params_s, seq), in_sh, _sasrec_flops(b), f"sasrec serve: B={b}")

        return build

    def retrieval_build(mesh, policy):
        rows = _row_axes(mesh)

        def step(params, seq, candidates):
            return R.sasrec_retrieval_score(params, SASREC, seq, candidates, TOPK)

        params_s = jax.eval_shape(lambda k: R.sasrec_init(k, SASREC), jax.random.PRNGKey(0))
        (seq,) = _sasrec_inputs(1)
        cands = jax.ShapeDtypeStruct((N_CAND, SASREC.embed_dim), jnp.float32)
        in_sh = (
            _shard_params(params_s, mesh, policy),
            shard(mesh, None, None),
            jax.sharding.NamedSharding(mesh, P(rows, None)),
        )
        return BuiltCell(step, (params_s, seq, cands), in_sh, 2.0 * N_CAND * SASREC.embed_dim,
                         f"sasrec retrieval: 1×{N_CAND:,}")

    return {
        "train_batch": _recsys_cell("sasrec", "train_batch", "train", train_build),
        "serve_p99": _recsys_cell("sasrec", "serve_p99", "serve", serve_build_factory(P99_B)),
        "serve_bulk": _recsys_cell("sasrec", "serve_bulk", "serve", serve_build_factory(BULK_B)),
        "retrieval_cand": _recsys_cell("sasrec", "retrieval_cand", "retrieval", retrieval_build),
    }


def _sasrec_flops(b):
    d, l = SASREC.embed_dim, SASREC.seq_len
    attn = 2 * b * SASREC.n_blocks * (3 * l * d * d + 2 * l * l * d)
    ffn = 2 * b * SASREC.n_blocks * 2 * l * d * d
    return float(attn + ffn)


# --------------------------------------------------------------------------- #
# Smokes                                                                       #
# --------------------------------------------------------------------------- #
def _dlrm_smoke():
    cfg = R.DLRMConfig(name="dlrm_smoke", vocab_sizes=(50, 30, 20), embed_dim=8,
                       bot_mlp=(16, 8), top_mlp=(16, 1))
    p = R.dlrm_init(jax.random.PRNGKey(0), cfg)
    dense = jax.random.normal(jax.random.PRNGKey(1), (8, 13))
    sparse = jnp.stack([jax.random.randint(jax.random.PRNGKey(i), (8,), 0, v)
                        for i, v in enumerate(cfg.vocab_sizes)], axis=1)
    loss = R.dlrm_loss(p, cfg, dense, sparse, jnp.ones((8,)))
    logits = R.dlrm_forward(p, cfg, dense, sparse)
    return {"loss": float(loss), "finite": bool(np.isfinite(np.asarray(logits)).all()),
            "logits_shape": tuple(logits.shape)}


def _deepfm_smoke():
    cfg = R.DeepFMConfig(name="fm_smoke", n_sparse=6, embed_dim=4, vocab_per_field=100, mlp=(16,))
    p = R.deepfm_init(jax.random.PRNGKey(0), cfg)
    ids = jax.random.randint(jax.random.PRNGKey(1), (8, 6), 0, 100)
    logits = R.deepfm_forward(p, cfg, ids)
    loss = R.deepfm_loss(p, cfg, ids, jnp.zeros((8,)))
    return {"loss": float(loss), "finite": bool(np.isfinite(np.asarray(logits)).all()),
            "logits_shape": tuple(logits.shape)}


def _mind_smoke():
    cfg = R.MINDConfig(name="mind_smoke", n_items=100, embed_dim=8, hist_len=6, n_negatives=16)
    p = R.mind_init(jax.random.PRNGKey(0), cfg)
    hist = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, 100)
    mask = jnp.ones((4, 6))
    caps = R.mind_interests(p, cfg, hist, mask)
    loss = R.mind_loss(p, cfg, hist, mask, jnp.zeros((4,), jnp.int32),
                       jnp.arange(16, dtype=jnp.int32))
    return {"loss": float(loss), "finite": bool(np.isfinite(np.asarray(caps)).all()),
            "caps_shape": tuple(caps.shape)}


def _sasrec_smoke():
    cfg = R.SASRecConfig(name="sas_smoke", n_items=50, embed_dim=8, n_blocks=1, seq_len=6)
    p = R.sasrec_init(jax.random.PRNGKey(0), cfg)
    seq = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 1, 50)
    h = R.sasrec_hidden(p, cfg, seq)
    loss = R.sasrec_loss(p, cfg, seq, seq, seq)
    return {"loss": float(loss), "finite": bool(np.isfinite(np.asarray(h)).all()),
            "hidden_shape": tuple(h.shape)}


@register("dlrm-mlperf")
def _dlrm_arch() -> Arch:
    return Arch("dlrm-mlperf", "recsys", _dlrm_cells, _dlrm_smoke,
                notes="MLPerf Criteo-1TB vocab (187.7M rows); row-sharded table")


@register("deepfm")
def _deepfm_arch() -> Arch:
    return Arch("deepfm", "recsys", _deepfm_cells, _deepfm_smoke, notes="FM identity + deep MLP")


@register("mind")
def _mind_arch() -> Arch:
    return Arch("mind", "recsys", _mind_cells, _mind_smoke, notes="B2I capsule routing, 4 interests")


@register("sasrec")
def _sasrec_arch() -> Arch:
    return Arch("sasrec", "recsys", _sasrec_cells, _sasrec_smoke, notes="2-block causal self-attn")
