"""jit'd wrapper for the EmbeddingBag kernel.

Handles the kernel's preconditions: sorts lookups by bag (stable), runs the
kernel, and zeroes bags that received no lookups (their output blocks are
never visited by the kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@functools.partial(jax.jit, static_argnames=("n_bags", "use_pallas", "interpret", "assume_sorted"))
def embedding_bag(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    segment_ids: jnp.ndarray,
    n_bags: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
    assume_sorted: bool = False,
) -> jnp.ndarray:
    """Sum-mode EmbeddingBag: (V, D) table, flat (indices, segment_ids) → (n_bags, D)."""
    use_pallas = (jax.default_backend() == "tpu") if use_pallas is None else use_pallas
    if not use_pallas:
        return embedding_bag_ref(table, indices, segment_ids, n_bags)
    if not assume_sorted:
        order = jnp.argsort(segment_ids, stable=True)
        indices = indices[order]
        segment_ids = segment_ids[order]
    out = embedding_bag_pallas(table, indices, segment_ids, n_bags, interpret=interpret)
    # zero never-visited bags
    visited = jnp.zeros((n_bags,), jnp.bool_).at[segment_ids].set(True)
    return jnp.where(visited[:, None], out, 0.0)
