"""EmbeddingBag (gather + segment-sum) as a Pallas TPU kernel.

The DLRM/DeepFM lookup hot path: the table lives in HBM (10⁶–10⁹ rows never
fit VMEM); lookup indices arrive as *scalar-prefetch* operands so the
BlockSpec index_map itself does the row indirection — each grid step DMAs
exactly the (1, D) table row it needs (TPU's analogue of FBGEMM TBE's
gather pipeline) and accumulates into the output bag row held in VMEM.

Requirements (enforced by ops.py):
* ``segment_ids`` sorted ascending — consecutive grid steps that share a bag
  revisit the same output block, which Pallas keeps resident in VMEM; the
  first visit zero-initializes (``pl.when`` on a segment boundary).
* bags with zero lookups are masked to zero by the wrapper (their output
  block is never visited).

Grid: (n_lookups,). Sequential by construction (output revisiting).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams; support both so the kernel
# runs (interpret or compiled) on either side of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _bag_kernel(idx_ref, seg_ref, table_row_ref, out_ref):
    i = pl.program_id(0)
    is_first = jnp.logical_or(i == 0, seg_ref[jnp.maximum(i - 1, 0)] != seg_ref[i])

    @pl.when(is_first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_row_ref[...].astype(out_ref.dtype)


def embedding_bag_pallas(
    table: jnp.ndarray,  # (V, D)
    indices: jnp.ndarray,  # (n_lookups,) int32, bag-sorted
    segment_ids: jnp.ndarray,  # (n_lookups,) int32 ascending
    n_bags: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    (n_lookups,) = indices.shape
    v, d = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # indices, segment_ids
        grid=(n_lookups,),
        in_specs=[
            # the row indirection: block (1, D) at row idx_ref[i]
            pl.BlockSpec((1, d), lambda i, idx_ref, seg_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref, seg_ref: (seg_ref[i], 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="embedding_bag",
    )(indices.astype(jnp.int32), segment_ids.astype(jnp.int32), table)
