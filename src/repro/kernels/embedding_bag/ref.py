"""Pure-jnp oracle for the embedding-bag kernel (= models.recsys.embedding_bag)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,  # (V, D)
    indices: jnp.ndarray,  # (n_lookups,) int32
    segment_ids: jnp.ndarray,  # (n_lookups,) int32 → bag
    n_bags: int,
    *,
    mode: str = "sum",
) -> jnp.ndarray:
    rows = table[indices]
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, rows.dtype), segment_ids, num_segments=n_bags
        )
        return s / jnp.maximum(c, 1.0)[:, None]
    raise ValueError(f"unsupported mode {mode!r}")
