"""jit'd wrapper for decode attention + the distributed (SP) combine.

``decode_attention`` — single-device dispatch (Pallas on TPU, oracle
elsewhere). ``decode_attention_sharded_body`` — the shard_map body for a KV
cache sharded along the sequence axis: each shard computes partial
(out·l, l, m) and the shards combine with a max/logsumexp reduction over the
mesh axis, which is exactly FlashDecoding's split-K reduction lifted to the
mesh level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("block_k", "use_pallas", "interpret"))
def decode_attention(
    q: jnp.ndarray,  # (B, H, dh)
    k: jnp.ndarray,  # (B, S, Hk, dh)
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    block_k: int = 512,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use_pallas = (jax.default_backend() == "tpu") if use_pallas is None else use_pallas
    if use_pallas:
        return decode_attention_pallas(
            q, k, v, lengths, block_k=block_k, interpret=interpret
        )
    return decode_attention_ref(q, k, v, lengths)


def _partial_softmax_stats(q, k, v, valid_mask, scale):
    """One shard's contribution: returns (acc (B,H,dh), l (B,H,1), m (B,H,1))."""
    b, h, dh = q.shape
    _, s, hk, _ = k.shape
    g = h // hk
    qg = q.reshape(b, hk, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg * scale, k.astype(jnp.float32))
    scores = jnp.where(valid_mask[:, None, None, :], scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)  # (B,Hk,G,1)
    m_safe = jnp.maximum(m, -1e30)
    p = jnp.exp(scores - m_safe)
    p = jnp.where(valid_mask[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return acc.reshape(b, h, dh), l.reshape(b, h, 1), m_safe.reshape(b, h, 1)


def decode_attention_sharded_body(
    q: jnp.ndarray,  # (B, H, dh) — replicated over the seq-shard axis
    k_shard: jnp.ndarray,  # (B, S_local, Hk, dh)
    v_shard: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) global lengths
    *,
    axis_name: str,
    scale: float | None = None,
) -> jnp.ndarray:
    """shard_map body: distributed flash-decode over ``axis_name``."""
    b, h, dh = q.shape
    s_local = k_shard.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    shard = jax.lax.axis_index(axis_name)
    start = shard * s_local
    pos = start + jnp.arange(s_local)[None, :]
    valid = pos < lengths[:, None]
    acc, l, m = _partial_softmax_stats(q, k_shard, v_shard, valid, scale)
    # combine across shards: global max, rescale, sum
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    acc = jax.lax.psum(acc * corr, axis_name)
    l = jax.lax.psum(l * corr, axis_name)
    safe_l = jnp.where(l == 0.0, 1.0, l)
    return (acc / safe_l).astype(q.dtype)
