"""Pure-jnp oracle for the decode-attention (flash-decoding) kernel.

Layout: q (B, H, dh) — one new token per sequence; cache k/v (B, S, Hk, dh);
lengths (B,) valid KV prefix per sequence. GQA via H = Hk * G.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(
    q: jnp.ndarray,  # (B, H, dh)
    k: jnp.ndarray,  # (B, S, Hk, dh)
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) int32
    *,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, dh = q.shape
    _, s, hk, _ = k.shape
    g = h // hk
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(b, hk, g, dh).astype(jnp.float32)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg * scale, k.astype(jnp.float32)
    )  # (B, Hk, G, S)
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)
