"""Decode attention (flash-decoding) as a Pallas TPU kernel.

The decode_32k/long_500k hot path: one query token per sequence against a
long KV cache. FlashDecoding splits the KV sequence into blocks and combines
partial softmax results via the running (m, l) state — the same online-
softmax recurrence as prefill flash attention, but with a (G, dh) query tile
(all q-heads of one kv head) instead of a (bq, dh) tile, so the MXU matmul
is (G, dh) × (dh, bk).

Grid: (B, Hk, n_kv_blocks), last dim sequential ("arbitrary") with VMEM
scratch carrying (m, l, acc). Per-sequence valid length arrives as a
scalar-prefetch operand (SMEM) and masks the tail block.

This kernel is also the single-shard body of the *distributed* flash-decode:
under SP the cache's S axis shards over ``model`` and the per-shard (m, l,
acc) combine with one all-reduce (see distributed/partition.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams; support both so the kernel
# runs (interpret or compiled) on either side of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # scalar-prefetch (B,) int32 in SMEM
    q_ref,  # (1, 1, G, dh)
    k_ref,  # (1, bk, 1, dh)
    v_ref,
    o_ref,  # (1, 1, G, dh)
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale,
    bk,
    n_kv,
):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[ib]
    k_start = ik * bk
    # Skip blocks entirely beyond the valid prefix.
    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, bk)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _store():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def _decode_kernel_q8(
    len_ref,  # scalar-prefetch (B,) int32
    q_ref,  # (1, 1, G, dh)
    k_ref,  # (1, bk, 1, dh) int8
    ks_ref,  # (1, bk, 1) f32 per-token-per-head scales
    v_ref,  # int8
    vs_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale,
    bk,
    n_kv,
):
    """int8-KV variant (KIVI-style): dequantize INSIDE the kernel so HBM
    traffic is the int8 payload + per-token scales (≈ 0.53× of bf16)."""
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[ib]
    k_start = ik * bk

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _store():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def decode_attention_q8_pallas(
    q: jnp.ndarray,  # (B, H, dh)
    k_q: jnp.ndarray,  # (B, S, Hk, dh) int8
    k_scale: jnp.ndarray,  # (B, S, Hk) f32
    v_q: jnp.ndarray,
    v_scale: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, dh = q.shape
    _, s, hk, _ = k_q.shape
    if h % hk:
        raise ValueError(f"GQA requires H % Hk == 0, got {h} % {hk}")
    g = h // hk
    bk = min(block_k, s)
    if s % bk:
        raise ValueError(f"cache len {s} must divide block_k {bk}")
    n_kv = s // bk
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    q4 = q.reshape(b, hk, g, dh)
    kernel = functools.partial(_decode_kernel_q8, scale=scale, bk=bk, n_kv=n_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hk, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda ib, ih, ik, lens: (ib, ik, ih, 0)),
            pl.BlockSpec((1, bk, 1), lambda ib, ih, ik, lens: (ib, ik, ih)),
            pl.BlockSpec((1, bk, 1, dh), lambda ib, ih, ik, lens: (ib, ik, ih, 0)),
            pl.BlockSpec((1, bk, 1), lambda ib, ih, ik, lens: (ib, ik, ih)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention_q8",
    )(lengths.astype(jnp.int32), q4, k_q, k_scale, v_q, v_scale)
    return out.reshape(b, h, dh)


def quantize_kv(k: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token-per-head absmax int8 quantization of a KV tensor
    (B, S, Hk, dh) → (int8 same shape, f32 scales (B, S, Hk))."""
    absmax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = absmax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / safe[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention_pallas(
    q: jnp.ndarray,  # (B, H, dh)
    k: jnp.ndarray,  # (B, S, Hk, dh)
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) int32
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, dh = q.shape
    _, s, hk, _ = k.shape
    if h % hk:
        raise ValueError(f"GQA requires H % Hk == 0, got {h} % {hk}")
    g = h // hk
    bk = min(block_k, s)
    if s % bk:
        raise ValueError(f"cache len {s} must divide block_k {bk}")
    n_kv = s // bk
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    q4 = q.reshape(b, hk, g, dh)
    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, n_kv=n_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hk, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda ib, ih, ik, lens: (ib, ik, ih, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda ib, ih, ik, lens: (ib, ik, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(lengths.astype(jnp.int32), q4, k, v)
    return out.reshape(b, h, dh)
