"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three files (see EXAMPLE.md):
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (backend dispatch, layout glue)
  ref.py    — pure-jnp oracle used by tests (interpret=True on CPU)

Kernels: flash_attention (prefill), decode_attention (flash-decoding),
mips_topk (fused retrieval scoring+selection), embedding_bag (recsys
gather-reduce).
"""
