"""Fused MIPS scoring + running top-k as a Pallas TPU kernel.

The retrieval hot path (paper §V.E FAISS role; recsys ``retrieval_cand``
cell: 1 query × 10⁶ candidates). TPU adaptation of FAISS's scan+heap: heaps
don't vectorize on the VPU, so selection is reformulated as k rounds of
(max, first-match-argmax, mask) over the candidate block — k is small
(≤ 32) and each round is a dense VPU reduction.

Grid: (n_q_blocks, n_corpus_blocks); corpus is the sequential axis. Scratch
carries the running (bq, k) best values/indices; each step fuses:

    scores = q_blk @ c_blkᵀ                     (MXU, bq × bn)
    merge running top-k with block top-k        (k VPU rounds)

so the (Q, N) score matrix never exists in HBM — the kernel's entire
working set is O(bq·bn) VMEM. Final block writes (vals, idx) out.

Why not materialize+sort: at N = 10⁶, Q = 8, f32 scores are 32 MB/query-
block + an O(N log N) sort; the fused form is HBM-bound on the corpus read
only — the roofline minimum for exact MIPS.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams; support both so the kernel
# runs (interpret or compiled) on either side of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _topk_merge(scores, base_idx, best_v, best_i, k):
    """Merge a (bq, bn) score block into running (bq, k) best lists.

    k rounds of: take row max of the remaining block, compare against the
    current worst of the running list, insert via a rank-shift. To keep it
    simple and fully vectorized we instead select the top-k of the
    *concatenated* candidate set [best (k) | block (bn)] by k rounds of
    (max, first-argmax, mask-out).
    """
    bq, bn = scores.shape
    cat_v = jnp.concatenate([best_v, scores], axis=1)  # (bq, k+bn)
    idx_block = base_idx + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    cat_i = jnp.concatenate([best_i, idx_block], axis=1)
    width = k + bn
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (bq, width), 1)

    new_v = []
    new_i = []
    for _ in range(k):
        m = jnp.max(cat_v, axis=1, keepdims=True)  # (bq, 1)
        hit = cat_v == m
        # first-match argmax via masked iota min
        pos = jnp.min(jnp.where(hit, col_iota, width), axis=1, keepdims=True)
        sel = col_iota == pos
        picked_i = jnp.sum(jnp.where(sel, cat_i, 0), axis=1, keepdims=True)
        new_v.append(m)
        new_i.append(picked_i)
        cat_v = jnp.where(sel, NEG_INF, cat_v)
    return jnp.concatenate(new_v, axis=1), jnp.concatenate(new_i, axis=1)


def _mips_kernel(q_ref, c_ref, v_out, i_out, bv_ref, bi_ref, *, k, bn, n_c, n_valid):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        bv_ref[...] = jnp.full_like(bv_ref, NEG_INF)
        bi_ref[...] = jnp.zeros_like(bi_ref)

    q = q_ref[...].astype(jnp.float32)  # (bq, D)
    c = c_ref[...].astype(jnp.float32)  # (bn, D)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn)
    if n_valid < n_c * bn:  # corpus was zero-padded: mask the pad columns out
        col = ic * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(col < n_valid, scores, NEG_INF)
    bv, bi = _topk_merge(scores, ic * bn, bv_ref[...], bi_ref[...], k)
    bv_ref[...] = bv
    bi_ref[...] = bi

    @pl.when(ic == n_c - 1)
    def _store():
        v_out[...] = bv_ref[...]
        i_out[...] = bi_ref[...]


def _mips_kernel_masked(q_ref, c_ref, m_ref, v_out, i_out, bv_ref, bi_ref, *, k, bn, n_c):
    """Variant taking a per-row validity mask as a traced input.

    Needed for the shard_map'd sharded-retrieval path: each shard's residue
    (how many of its rows are real vs zero-pad) depends on
    ``lax.axis_index``, so it is a *traced* value — the static ``n_valid``
    branch of :func:`_mips_kernel` cannot express it. The mask rides the
    same grid as the corpus blocks ((1, bn) per step), so masking stays a
    VPU ``where`` with no extra HBM traffic beyond one f32 row.
    """
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        bv_ref[...] = jnp.full_like(bv_ref, NEG_INF)
        bi_ref[...] = jnp.zeros_like(bi_ref)

    q = q_ref[...].astype(jnp.float32)  # (bq, D)
    c = c_ref[...].astype(jnp.float32)  # (bn, D)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn)
    mask = m_ref[...] > 0.0  # (1, bn), broadcasts over query rows
    scores = jnp.where(mask, scores, NEG_INF)
    bv, bi = _topk_merge(scores, ic * bn, bv_ref[...], bi_ref[...], k)
    bv_ref[...] = bv
    bi_ref[...] = bi

    @pl.when(ic == n_c - 1)
    def _store():
        v_out[...] = bv_ref[...]
        i_out[...] = bi_ref[...]


def mips_topk_pallas(
    queries: jnp.ndarray,  # (Q, D)
    corpus: jnp.ndarray,  # (N, D)
    k: int,
    *,
    block_q: int = 8,
    block_n: int = 1024,
    n_valid: int | None = None,
    valid_mask: jnp.ndarray | None = None,
    interpret: bool = False,
):
    """Fused MIPS top-k over a (possibly zero-padded) corpus.

    Two masking modes for padded rows, mutually exclusive:

    * ``n_valid`` (static int) — rows at index >= n_valid are masked to
      -inf; callers pad N up to a block multiple (DenseIndex's auto-pad).
    * ``valid_mask`` (traced ``(N,)`` float array, >0 = real row) — same
      masking as a kernel *input*, for callers whose residue is only known
      at trace time: inside ``shard_map`` each shard's valid-row count
      derives from ``lax.axis_index``, which a static int cannot capture.
      With a traced mask the k-vs-corpus-size check is the caller's job
      (the sharded path clamps k before building the closure).
    """
    q_n, d = queries.shape
    n, _ = corpus.shape
    if valid_mask is not None and n_valid is not None:
        raise ValueError("pass n_valid (static) or valid_mask (traced), not both")
    bq = min(block_q, q_n)
    bn = min(block_n, n)
    if q_n % bq or n % bn:
        raise ValueError(f"(Q={q_n}, N={n}) must divide blocks ({bq}, {bn})")
    if k > bn:
        raise ValueError(f"k={k} must be <= block_n={bn}")
    n_q, n_c = q_n // bq, n // bn

    common = dict(
        grid=(n_q, n_c),
        out_specs=[
            pl.BlockSpec((bq, k), lambda iq, ic: (iq, 0)),
            pl.BlockSpec((bq, k), lambda iq, ic: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k), jnp.float32),
            jax.ShapeDtypeStruct((q_n, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mips_topk",
    )
    if valid_mask is not None:
        if valid_mask.shape != (n,):
            raise ValueError(f"valid_mask must be ({n},), got {valid_mask.shape}")
        kernel = functools.partial(_mips_kernel_masked, k=k, bn=bn, n_c=n_c)
        vals, idx = pl.pallas_call(
            kernel,
            in_specs=[
                pl.BlockSpec((bq, d), lambda iq, ic: (iq, 0)),
                pl.BlockSpec((bn, d), lambda iq, ic: (ic, 0)),
                pl.BlockSpec((1, bn), lambda iq, ic: (0, ic)),
            ],
            **common,
        )(queries, corpus, valid_mask.astype(jnp.float32)[None, :])
        return vals, idx

    n_valid = n if n_valid is None else n_valid
    if not 0 < n_valid <= n:
        raise ValueError(f"n_valid={n_valid} must be in (0, {n}]")
    if k > n_valid:
        raise ValueError(f"k={k} > corpus size {n_valid}")
    kernel = functools.partial(_mips_kernel, k=k, bn=bn, n_c=n_c, n_valid=n_valid)
    vals, idx = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((bq, d), lambda iq, ic: (iq, 0)),
            pl.BlockSpec((bn, d), lambda iq, ic: (ic, 0)),
        ],
        **common,
    )(queries, corpus)
    return vals, idx
