"""Fused MIPS scoring + running top-k as a Pallas TPU kernel.

The retrieval hot path (paper §V.E FAISS role; recsys ``retrieval_cand``
cell: 1 query × 10⁶ candidates). TPU adaptation of FAISS's scan+heap: heaps
don't vectorize on the VPU, so selection is reformulated as k rounds of
(max, first-match-argmax, mask) over the candidate block — k is small
(≤ 32) and each round is a dense VPU reduction.

Grid: (n_q_blocks, n_corpus_blocks); corpus is the sequential axis. Scratch
carries the running (bq, k) best values/indices; each step fuses:

    scores = q_blk @ c_blkᵀ                     (MXU, bq × bn)
    merge running top-k with block top-k        (k VPU rounds)

so the (Q, N) score matrix never exists in HBM — the kernel's entire
working set is O(bq·bn) VMEM. Final block writes (vals, idx) out.

Why not materialize+sort: at N = 10⁶, Q = 8, f32 scores are 32 MB/query-
block + an O(N log N) sort; the fused form is HBM-bound on the corpus read
only — the roofline minimum for exact MIPS.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams; support both so the kernel
# runs (interpret or compiled) on either side of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _topk_merge(scores, base_idx, best_v, best_i, k):
    """Merge a (bq, bn) score block into running (bq, k) best lists.

    k rounds of: take row max of the remaining block, compare against the
    current worst of the running list, insert via a rank-shift. To keep it
    simple and fully vectorized we instead select the top-k of the
    *concatenated* candidate set [best (k) | block (bn)] by k rounds of
    (max, first-argmax, mask-out).
    """
    bq, bn = scores.shape
    cat_v = jnp.concatenate([best_v, scores], axis=1)  # (bq, k+bn)
    idx_block = base_idx + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    cat_i = jnp.concatenate([best_i, idx_block], axis=1)
    width = k + bn
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (bq, width), 1)

    new_v = []
    new_i = []
    for _ in range(k):
        m = jnp.max(cat_v, axis=1, keepdims=True)  # (bq, 1)
        hit = cat_v == m
        # first-match argmax via masked iota min
        pos = jnp.min(jnp.where(hit, col_iota, width), axis=1, keepdims=True)
        sel = col_iota == pos
        picked_i = jnp.sum(jnp.where(sel, cat_i, 0), axis=1, keepdims=True)
        new_v.append(m)
        new_i.append(picked_i)
        cat_v = jnp.where(sel, NEG_INF, cat_v)
    return jnp.concatenate(new_v, axis=1), jnp.concatenate(new_i, axis=1)


def _mips_kernel(q_ref, c_ref, v_out, i_out, bv_ref, bi_ref, *, k, bn, n_c, n_valid):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        bv_ref[...] = jnp.full_like(bv_ref, NEG_INF)
        bi_ref[...] = jnp.zeros_like(bi_ref)

    q = q_ref[...].astype(jnp.float32)  # (bq, D)
    c = c_ref[...].astype(jnp.float32)  # (bn, D)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn)
    if n_valid < n_c * bn:  # corpus was zero-padded: mask the pad columns out
        col = ic * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(col < n_valid, scores, NEG_INF)
    bv, bi = _topk_merge(scores, ic * bn, bv_ref[...], bi_ref[...], k)
    bv_ref[...] = bv
    bi_ref[...] = bi

    @pl.when(ic == n_c - 1)
    def _store():
        v_out[...] = bv_ref[...]
        i_out[...] = bi_ref[...]


def mips_topk_pallas(
    queries: jnp.ndarray,  # (Q, D)
    corpus: jnp.ndarray,  # (N, D)
    k: int,
    *,
    block_q: int = 8,
    block_n: int = 1024,
    n_valid: int | None = None,
    interpret: bool = False,
):
    """Fused MIPS top-k. ``n_valid`` supports zero-padded corpora: rows at
    index >= n_valid are masked to -inf so callers can pad N up to a block
    multiple without polluting the candidate set (DenseIndex's auto-pad)."""
    q_n, d = queries.shape
    n, _ = corpus.shape
    n_valid = n if n_valid is None else n_valid
    if not 0 < n_valid <= n:
        raise ValueError(f"n_valid={n_valid} must be in (0, {n}]")
    if k > n_valid:
        raise ValueError(f"k={k} > corpus size {n_valid}")
    bq = min(block_q, q_n)
    bn = min(block_n, n)
    if q_n % bq or n % bn:
        raise ValueError(f"(Q={q_n}, N={n}) must divide blocks ({bq}, {bn})")
    if k > bn:
        raise ValueError(f"k={k} must be <= block_n={bn}")
    n_q, n_c = q_n // bq, n // bn

    kernel = functools.partial(_mips_kernel, k=k, bn=bn, n_c=n_c, n_valid=n_valid)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(n_q, n_c),
        in_specs=[
            pl.BlockSpec((bq, d), lambda iq, ic: (iq, 0)),
            pl.BlockSpec((bn, d), lambda iq, ic: (ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda iq, ic: (iq, 0)),
            pl.BlockSpec((bq, k), lambda iq, ic: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k), jnp.float32),
            jax.ShapeDtypeStruct((q_n, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mips_topk",
    )(queries, corpus)
    return vals, idx
