"""jit'd wrapper for fused MIPS top-k retrieval scoring."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mips_topk.kernel import mips_topk_pallas
from repro.kernels.mips_topk.ref import mips_topk_ref


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_q", "block_n", "n_valid", "use_pallas", "interpret"),
)
def mips_topk(
    queries: jnp.ndarray,
    corpus: jnp.ndarray,
    k: int,
    *,
    block_q: int = 8,
    block_n: int = 1024,
    n_valid: int | None = None,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    """Exact MIPS top-k: (Q, D) × (N, D) → ((Q, k) scores, (Q, k) int32 ids).

    ``n_valid`` masks zero-padded corpus rows (see ``mips_topk_pallas``).
    """
    use_pallas = (jax.default_backend() == "tpu") if use_pallas is None else use_pallas
    if use_pallas:
        return mips_topk_pallas(
            queries, corpus, k,
            block_q=block_q, block_n=block_n, n_valid=n_valid, interpret=interpret,
        )
    if n_valid is not None:
        corpus = corpus[:n_valid]
    return mips_topk_ref(queries, corpus, k)
