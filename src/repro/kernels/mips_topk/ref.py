"""Pure-jnp oracle for the fused MIPS+top-k retrieval kernel.

Contract: queries (Q, D), corpus (N, D) → (scores (Q, K), indices (Q, K)),
scores descending per row; indices are corpus rows. Ties broken by lower
index (matches the kernel's first-match argmax emulation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mips_topk_ref(queries: jnp.ndarray, corpus: jnp.ndarray, k: int):
    scores = queries.astype(jnp.float32) @ corpus.astype(jnp.float32).T  # (Q, N)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
