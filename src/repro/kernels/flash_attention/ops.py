"""jit'd public wrapper for the flash-attention kernel.

``flash_attention(q, k, v)`` takes the model-layout tensors
(B, S, H, dh)/(B, S, Hk, dh) (see models/layers.py), transposes to the
kernel's (B, H, S, dh) layout, pads sequence to block multiples, and
dispatches to the Pallas kernel (TPU) or the jnp oracle (other backends).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "use_pallas", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, dh) — model layout
    k: jnp.ndarray,  # (B, S, Hk, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    qt = jnp.swapaxes(q, 1, 2)  # (B, H, S, dh)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if not use_pallas:
        out = attention_ref(qt, kt, vt, causal=causal)
    else:
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
        )
    return jnp.swapaxes(out, 1, 2)
