"""Pure-jnp oracle for the flash-attention kernel.

Layout contract (matches kernel.py): q (B, H, Sq, dh); k, v (B, Hk, Skv, dh)
with H = Hk * G. Causal masking aligns the *ends* of q and kv (standard
prefill: q_pos = i + Skv - Sq).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import jax


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    _, hk, skv, _ = k.shape
    assert h % hk == 0, (h, hk)
    g = h // hk
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(b, hk, g, sq, dh).astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg * scale, k.astype(jnp.float32))
    if causal:
        q_pos = jnp.arange(sq) + (skv - sq)
        kv_pos = jnp.arange(skv)
        mask = kv_pos[None, :] <= q_pos[:, None]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, sq, dh).astype(q.dtype)
