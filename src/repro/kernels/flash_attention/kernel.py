"""Flash attention (causal, GQA) as a Pallas TPU kernel.

Adaptation of FlashAttention's IO-aware tiling to the TPU memory hierarchy:
Q/K/V stream HBM→VMEM in MXU-aligned blocks; the online-softmax state
(running max m, normalizer l, accumulator acc) lives in VMEM scratch and
persists across the innermost (sequential) KV-block grid dimension.

Grid: (B, H, n_q_blocks, n_kv_blocks) — the last dim is "arbitrary"
(sequential) so scratch carries across KV blocks; init at kv_idx == 0, final
normalize+store at the last kv block. Causal skipping: fully-masked KV
blocks (block start beyond the q block's last row) are no-ops via pl.when.

BlockSpecs (VMEM):
    q   (1, 1, bq, dh)   index (b, h, iq, ik) → (b, h, iq, 0)
    k/v (1, 1, bk, dh)   index (b, h, iq, ik) → (b, h // G, ik, 0)   [GQA]
    out (1, 1, bq, dh)   index (b, h, iq, ik) → (b, h, iq, 0)
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams; support both so the kernel
# runs (interpret or compiled) on either side of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, bq, bk, n_kv
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal block skip: with equal-length q/kv (prefill), kv block start
    # beyond q block end contributes nothing.
    q_start = iq * bq
    k_start = ik * bk
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _store():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, H, Sq, dh)
    k: jnp.ndarray,  # (B, Hk, Skv, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    _, hk, skv, _ = k.shape
    if h % hk:
        raise ValueError(f"GQA requires H % Hk == 0, got {h} % {hk}")
    g = h // hk
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq lens ({sq},{skv}) must divide blocks ({bq},{bk})")
    n_q, n_kv = sq // bq, skv // bk
    if causal and sq != skv:
        raise ValueError("kernel causal path assumes Sq == Skv (prefill)")
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_kv=n_kv
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # m
            pltpu.VMEM((bq, 1), jnp.float32),  # l
            pltpu.VMEM((bq, dh), jnp.float32),  # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
